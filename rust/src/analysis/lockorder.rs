//! `lock-order`: rank-checked lock acquisition.
//!
//! The repo's locks are few and deliberate — the table store's single
//! `Mutex<Inner>`, the coordinator queue and metrics mutexes, the
//! planner's policy `RwLock` — but one nesting does exist
//! (`Metrics::snapshot` holds the metrics lock while calling
//! `TableStore::stats`), and nothing used to stop a future edit from
//! closing that into a cycle. This pass makes the discipline checkable:
//!
//! - Each lock field/static is annotated at its declaration:
//!   `// pcilt-lint: lock-rank(<name> = <rank>)`. Ranks are global; a
//!   thread may only acquire locks in strictly increasing rank order.
//! - A function that acquires a lock internally (so callers can nest it
//!   under their own guard) is annotated `// pcilt-lint: acquires(<name>)`;
//!   call sites then count as acquisitions of `<name>` — this is how the
//!   metrics → store edge is seen across module boundaries.
//!
//! Within every `fn` body the pass tracks guard bindings (`let g = ...`),
//! explicit `drop(g)` releases and block-scope expiry, and reports any
//! acquisition whose rank does not exceed every held lock's rank. The
//! tracking is lexical, not a borrow checker: guards moved across
//! functions or stored in structs are out of scope (none exist here) —
//! the point is to catch the easy-to-introduce nesting regressions.

use std::collections::BTreeMap;

use super::lexer::TokenKind;
use super::report::Diagnostic;
use super::rules::{fn_bodies, plain_comment, suppressed_lines, FileData, PRAGMA};

/// Methods whose call on an annotated lock ident is an acquisition.
const ACQUIRE_METHODS: &[&str] = &["lock", "read", "write"];

/// One annotated lock: global name, rank, declaring file ident.
struct LockDecl {
    rank: u32,
    file: String,
    line: u32,
}

/// Everything the annotation pass collects across files.
#[derive(Default)]
struct Annotations {
    /// Lock name -> rank + declaration site.
    locks: BTreeMap<String, LockDecl>,
    /// Per file: local ident (field/static name) -> lock name.
    idents: BTreeMap<String, BTreeMap<String, String>>,
    /// Method name -> lock name (from `acquires(...)` annotations).
    acquires: BTreeMap<String, String>,
    diags: Vec<Diagnostic>,
}

/// Run the lock-order pass over all scanned files.
pub fn scan(files: &[FileData]) -> Vec<Diagnostic> {
    let ann = collect(files);
    let mut out = ann.diags.clone();
    for f in files {
        out.extend(check_file(f, &ann));
    }
    out
}

fn collect(files: &[FileData]) -> Annotations {
    let mut ann = Annotations::default();
    for f in files {
        let code: Vec<usize> =
            (0..f.toks.len()).filter(|&i| f.toks[i].kind != TokenKind::Comment).collect();
        for (i, t) in f.toks.iter().enumerate() {
            if t.kind != TokenKind::Comment {
                continue;
            }
            let text = t.text(&f.src);
            if !plain_comment(text) {
                continue;
            }
            let Some(at) = text.find(PRAGMA) else { continue };
            let rest = text[at + PRAGMA.len()..].trim_start();
            if let Some((name, rank)) = parse_lock_rank(rest) {
                let Some(ident) = next_field_ident(f, &code, i) else {
                    ann.diags.push(Diagnostic::new(
                        &f.rel,
                        t.line,
                        "lock-order",
                        format!("lock-rank({name}) is not followed by a field or static"),
                    ));
                    continue;
                };
                if let Some(prev) = ann.locks.get(&name) {
                    ann.diags.push(Diagnostic::new(
                        &f.rel,
                        t.line,
                        "lock-order",
                        format!(
                            "lock `{name}` already declared in {}:{}",
                            prev.file, prev.line
                        ),
                    ));
                    continue;
                }
                ann.locks.insert(
                    name.clone(),
                    LockDecl { rank, file: f.rel.clone(), line: t.line },
                );
                ann.idents.entry(f.rel.clone()).or_default().insert(ident, name);
            } else if let Some(name) = parse_acquires(rest) {
                let Some(fn_name) = next_fn_name(f, &code, i) else {
                    ann.diags.push(Diagnostic::new(
                        &f.rel,
                        t.line,
                        "lock-order",
                        format!("acquires({name}) is not followed by a fn"),
                    ));
                    continue;
                };
                ann.acquires.insert(fn_name, name);
            }
        }
    }
    // `acquires(...)` must name a declared lock.
    for (fn_name, lock) in &ann.acquires {
        if !ann.locks.contains_key(lock) {
            ann.diags.push(Diagnostic::new(
                "",
                0,
                "lock-order",
                format!("acquires({lock}) on fn `{fn_name}` names an undeclared lock"),
            ));
        }
    }
    ann
}

/// `lock-rank(name = rank)` -> (name, rank).
fn parse_lock_rank(rest: &str) -> Option<(String, u32)> {
    let body = rest.strip_prefix("lock-rank(")?;
    let end = body.find(')')?;
    let (name, rank) = body[..end].split_once('=')?;
    Some((name.trim().to_string(), rank.trim().parse().ok()?))
}

/// `acquires(name)` -> name.
fn parse_acquires(rest: &str) -> Option<String> {
    let body = rest.strip_prefix("acquires(")?;
    let end = body.find(')')?;
    Some(body[..end].trim().to_string())
}

/// First ident after token `i` that is directly followed by `:` — the
/// field or static name the annotation binds to. Bounded lookahead so a
/// stray annotation cannot bind across items.
fn next_field_ident(f: &FileData, code: &[usize], i: usize) -> Option<String> {
    let start = code.partition_point(|&c| c < i);
    for w in code[start..].windows(2).take(12) {
        if f.toks[w[0]].kind == TokenKind::Ident && f.toks[w[1]].text(&f.src) == ":" {
            return Some(f.toks[w[0]].text(&f.src).to_string());
        }
    }
    None
}

/// Name of the first `fn` after token `i` (bounded lookahead).
fn next_fn_name(f: &FileData, code: &[usize], i: usize) -> Option<String> {
    let start = code.partition_point(|&c| c < i);
    for w in code[start..].windows(2).take(12) {
        if f.toks[w[0]].text(&f.src) == "fn" && f.toks[w[1]].kind == TokenKind::Ident {
            return Some(f.toks[w[1]].text(&f.src).to_string());
        }
    }
    None
}

/// A lock currently held in the simulation.
struct Held {
    lock: String,
    /// Guard binding, if the acquisition was a `let` (None = transient).
    guard: Option<String>,
    /// Brace depth at the binding — scope exit below this releases it.
    depth: i32,
    line: u32,
}

fn check_file(f: &FileData, ann: &Annotations) -> Vec<Diagnostic> {
    let empty = BTreeMap::new();
    let local = ann.idents.get(&f.rel).unwrap_or(&empty);
    // Held locks only enter via local acquisitions, so files declaring
    // no locks cannot produce ordering diagnostics.
    if local.is_empty() {
        return Vec::new();
    }
    let sup = suppressed_lines(f, "lock-order");
    let code: Vec<usize> =
        (0..f.toks.len()).filter(|&i| f.toks[i].kind != TokenKind::Comment).collect();
    let mut out = Vec::new();
    for fb in fn_bodies(f) {
        if f.toks[fb.name_idx].text(&f.src) == "drop" {
            continue; // don't confuse a local `fn drop` impl with releases
        }
        let lo = code.partition_point(|&c| c < fb.body.0);
        let hi = code.partition_point(|&c| c <= fb.body.1);
        simulate(f, &code[lo..hi], local, ann, &sup, &mut out);
    }
    out.sort_by(|a, b| (a.line, a.message.clone()).cmp(&(b.line, b.message.clone())));
    out.dedup();
    out
}

/// Walk one fn body's code tokens, tracking held locks and flagging
/// acquisitions that don't strictly increase in rank.
fn simulate(
    f: &FileData,
    body: &[usize],
    local: &BTreeMap<String, String>,
    ann: &Annotations,
    sup: &std::collections::BTreeSet<u32>,
    out: &mut Vec<Diagnostic>,
) {
    let text = |ci: usize| f.toks[body[ci]].text(&f.src);
    let mut held: Vec<Held> = Vec::new();
    let mut depth = 0i32;
    let mut pending_let: Option<String> = None;
    for ci in 0..body.len() {
        let t = text(ci);
        match t {
            "{" => {
                depth += 1;
                continue;
            }
            "}" => {
                depth -= 1;
                held.retain(|h| h.depth <= depth);
                continue;
            }
            ";" => {
                pending_let = None;
                continue;
            }
            "let" if f.toks[body[ci]].kind == TokenKind::Ident => {
                // Capture the binding ident (skip `mut`); patterns that
                // aren't simple idents never bind guards in this repo.
                let mut j = ci + 1;
                if j < body.len() && text(j) == "mut" {
                    j += 1;
                }
                if j < body.len() && f.toks[body[j]].kind == TokenKind::Ident {
                    pending_let = Some(text(j).to_string());
                }
                continue;
            }
            "drop" => {
                if ci + 2 < body.len() && text(ci + 1) == "(" {
                    let g = text(ci + 2).to_string();
                    held.retain(|h| h.guard.as_deref() != Some(g.as_str()));
                }
                continue;
            }
            _ => {}
        }
        if f.toks[body[ci]].kind != TokenKind::Ident {
            continue;
        }
        // Direct acquisition: `<lock-ident> . lock|read|write (`.
        if let Some(lock) = local.get(t) {
            let is_acq = ci + 3 < body.len()
                && text(ci + 1) == "."
                && ACQUIRE_METHODS.contains(&text(ci + 2))
                && text(ci + 3) == "(";
            if is_acq {
                let line = f.toks[body[ci]].line;
                report_order(f, &held, lock, line, ann, sup, out);
                held.push(Held {
                    lock: lock.clone(),
                    guard: pending_let.clone(),
                    depth,
                    line,
                });
                continue;
            }
        }
        // Cross-module acquisition: `.annotated_fn(` where the callee is
        // declared `acquires(<lock>)`. Transient: acquired and released
        // inside the call.
        if let Some(lock) = ann.acquires.get(t) {
            let is_call =
                ci > 0 && text(ci - 1) == "." && ci + 1 < body.len() && text(ci + 1) == "(";
            if is_call && !held.is_empty() {
                report_order(f, &held, lock, f.toks[body[ci]].line, ann, sup, out);
            }
        }
    }
}

/// Emit a diagnostic if acquiring `lock` while anything in `held` has an
/// equal or higher rank.
fn report_order(
    f: &FileData,
    held: &[Held],
    lock: &str,
    line: u32,
    ann: &Annotations,
    sup: &std::collections::BTreeSet<u32>,
    out: &mut Vec<Diagnostic>,
) {
    if sup.contains(&line) {
        return;
    }
    let rank = |name: &str| ann.locks.get(name).map(|l| l.rank);
    let Some(new_rank) = rank(lock) else { return };
    for h in held {
        if h.lock == lock {
            out.push(Diagnostic::new(
                &f.rel,
                line,
                "lock-order",
                format!("re-acquiring `{lock}` already held since line {}", h.line),
            ));
        } else if rank(&h.lock).is_some_and(|r| r >= new_rank) {
            out.push(Diagnostic::new(
                &f.rel,
                line,
                "lock-order",
                format!(
                    "acquiring `{lock}` (rank {new_rank}) while holding `{}` (rank {}) — \
                     ranks must strictly increase",
                    h.lock,
                    rank(&h.lock).unwrap_or(0),
                ),
            ));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fd(rel: &str, src: &str) -> FileData {
        FileData::new(rel.to_string(), src.to_string())
    }

    const DECLS: &str = "pub struct S {\n\
        // pcilt-lint: lock-rank(low = 10)\n\
        low: Mutex<u32>,\n\
        // pcilt-lint: lock-rank(high = 30)\n\
        high: Mutex<u32>,\n\
    }\n";

    #[test]
    fn rank_violation_is_flagged() {
        let src = format!(
            "{DECLS}impl S {{\n    fn bad(&self) {{\n        let g = self.high.lock().unwrap();\n\
             \n        let h = self.low.lock().unwrap();\n    }}\n}}\n"
        );
        let d = scan(&[fd("coordinator/s.rs", &src)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].line, 11);
        assert!(d[0].message.contains("`low` (rank 10) while holding `high` (rank 30)"));
    }

    #[test]
    fn increasing_ranks_are_fine() {
        let src = format!(
            "{DECLS}impl S {{\n    fn good(&self) {{\n        let g = self.low.lock().unwrap();\n\
             \n        let h = self.high.lock().unwrap();\n    }}\n}}\n"
        );
        assert!(scan(&[fd("coordinator/s.rs", &src)]).is_empty());
    }

    #[test]
    fn drop_and_scope_release() {
        let src = format!(
            "{DECLS}impl S {{\n    fn seq(&self) {{\n        \
             {{ let g = self.high.lock().unwrap(); }}\n\
             \n        let h = self.high.lock().unwrap();\n        drop(h);\n\
             \n        let k = self.low.lock().unwrap();\n    }}\n}}\n"
        );
        assert!(scan(&[fd("coordinator/s.rs", &src)]).is_empty(), "scoped guards release");
    }

    #[test]
    fn reacquire_same_lock_is_flagged() {
        let src = format!(
            "{DECLS}impl S {{\n    fn twice(&self) {{\n        let g = self.low.lock().unwrap();\n\
             \n        let h = self.low.lock().unwrap();\n    }}\n}}\n"
        );
        let d = scan(&[fd("coordinator/s.rs", &src)]);
        assert_eq!(d.len(), 1);
        assert!(d[0].message.contains("re-acquiring `low`"));
    }

    #[test]
    fn cross_module_acquires_annotation() {
        let store = "pub struct T {\n\
            // pcilt-lint: lock-rank(store = 30)\n\
            inner: Mutex<u32>,\n}\n\
            impl T {\n\
            // pcilt-lint: acquires(store)\n\
            pub fn stats(&self) -> u32 { *self.inner.lock().unwrap() }\n}\n";
        let metrics_bad = "pub struct M {\n\
            // pcilt-lint: lock-rank(metrics = 40)\n\
            inner: Mutex<u32>,\n}\n\
            impl M {\n\
            fn snapshot(&self) {\n    let g = self.inner.lock().unwrap();\n\
            \n    let s = self.store.stats();\n}\n}\n";
        let d = scan(&[fd("pcilt/store.rs", store), fd("coordinator/metrics.rs", metrics_bad)]);
        assert_eq!(d.len(), 1, "{d:?}");
        assert_eq!(d[0].file, "coordinator/metrics.rs");
        assert!(d[0].message.contains("`store` (rank 30) while holding `metrics` (rank 40)"));
        // With metrics ranked below store the same shape is legal.
        let metrics_good =
            metrics_bad.replace("lock-rank(metrics = 40)", "lock-rank(metrics = 20)");
        let d = scan(&[fd("pcilt/store.rs", store), fd("coordinator/metrics.rs", &metrics_good)]);
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn duplicate_lock_name_rejected() {
        let a = "struct A {\n// pcilt-lint: lock-rank(q = 10)\n    inner: Mutex<u32>,\n}\n";
        let b = "struct B {\n// pcilt-lint: lock-rank(q = 20)\n    inner: Mutex<u32>,\n}\n";
        let d = scan(&[fd("coordinator/a.rs", a), fd("coordinator/b.rs", b)]);
        assert_eq!(d.len(), 1);
        assert!(d[0].message.contains("already declared"));
    }

    #[test]
    fn pragma_suppresses_violation() {
        let src = format!(
            "{DECLS}impl S {{\n    fn bad(&self) {{\n        let g = self.high.lock().unwrap();\n\
             \n        // pcilt-lint: allow(lock-order)\n        \
             let h = self.low.lock().unwrap();\n    }}\n}}\n"
        );
        assert!(scan(&[fd("coordinator/s.rs", &src)]).is_empty());
    }
}
