//! 4-D shape with row-major (NHWC) strides.

use std::fmt;

/// Shape of a rank-4 tensor, `[n, h, w, c]`, row-major.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Shape4 {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl Shape4 {
    pub fn new(n: usize, h: usize, w: usize, c: usize) -> Self {
        Self { n, h, w, c }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.n * self.h * self.w * self.c
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Flat index of `[n, h, w, c]`.
    #[inline(always)]
    pub fn index(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        debug_assert!(n < self.n && h < self.h && w < self.w && c < self.c,
            "index [{n},{h},{w},{c}] out of shape {self}");
        ((n * self.h + h) * self.w + w) * self.c + c
    }

    /// Strides `[n, h, w, c]` in elements.
    pub fn strides(&self) -> [usize; 4] {
        [self.h * self.w * self.c, self.w * self.c, self.c, 1]
    }

    /// Output spatial shape of a valid (unpadded) convolution with a
    /// `kh × kw` kernel and stride `(sy, sx)`.
    pub fn conv_out(&self, kh: usize, kw: usize, sy: usize, sx: usize) -> (usize, usize) {
        assert!(self.h >= kh && self.w >= kw,
            "kernel {kh}x{kw} larger than input {}x{}", self.h, self.w);
        assert!(sy > 0 && sx > 0);
        ((self.h - kh) / sy + 1, (self.w - kw) / sx + 1)
    }
}

impl fmt::Display for Shape4 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{},{},{},{}]", self.n, self.h, self.w, self.c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn len_and_strides() {
        let s = Shape4::new(2, 3, 4, 5);
        assert_eq!(s.len(), 120);
        assert_eq!(s.strides(), [60, 20, 5, 1]);
    }

    #[test]
    fn index_is_row_major() {
        let s = Shape4::new(2, 3, 4, 5);
        assert_eq!(s.index(0, 0, 0, 0), 0);
        assert_eq!(s.index(0, 0, 0, 4), 4);
        assert_eq!(s.index(0, 0, 1, 0), 5);
        assert_eq!(s.index(0, 1, 0, 0), 20);
        assert_eq!(s.index(1, 0, 0, 0), 60);
        assert_eq!(s.index(1, 2, 3, 4), 119);
    }

    #[test]
    fn conv_out_shapes() {
        let s = Shape4::new(1, 16, 16, 3);
        assert_eq!(s.conv_out(5, 5, 1, 1), (12, 12));
        assert_eq!(s.conv_out(3, 3, 2, 2), (7, 7));
        assert_eq!(s.conv_out(16, 16, 1, 1), (1, 1));
    }

    #[test]
    #[should_panic]
    fn conv_out_rejects_oversized_kernel() {
        Shape4::new(1, 4, 4, 1).conv_out(5, 5, 1, 1);
    }
}
