//! Owned rank-4 tensor over a copyable element type.

use std::fmt;

use crate::util::prng::Rng;

use super::Shape4;

/// Dense rank-4 tensor, row-major NHWC (or OHWI for filters).
#[derive(Clone, PartialEq)]
pub struct Tensor4<T> {
    shape: Shape4,
    data: Vec<T>,
}

impl<T: Copy + Default> Tensor4<T> {
    /// Zero-filled tensor.
    pub fn zeros(shape: Shape4) -> Self {
        Self {
            shape,
            data: vec![T::default(); shape.len()],
        }
    }

    /// Build from existing data (length must match the shape).
    pub fn from_vec(shape: Shape4, data: Vec<T>) -> Self {
        assert_eq!(
            data.len(),
            shape.len(),
            "data length {} != shape {} ({} elements)",
            data.len(),
            shape,
            shape.len()
        );
        Self { shape, data }
    }

    /// Fill via a function of the 4 indices.
    pub fn from_fn(shape: Shape4, mut f: impl FnMut(usize, usize, usize, usize) -> T) -> Self {
        let mut data = Vec::with_capacity(shape.len());
        for n in 0..shape.n {
            for h in 0..shape.h {
                for w in 0..shape.w {
                    for c in 0..shape.c {
                        data.push(f(n, h, w, c));
                    }
                }
            }
        }
        Self { shape, data }
    }

    pub fn shape(&self) -> Shape4 {
        self.shape
    }

    pub fn data(&self) -> &[T] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<T> {
        self.data
    }

    #[inline(always)]
    pub fn get(&self, n: usize, h: usize, w: usize, c: usize) -> T {
        self.data[self.shape.index(n, h, w, c)]
    }

    #[inline(always)]
    pub fn set(&mut self, n: usize, h: usize, w: usize, c: usize, v: T) {
        let i = self.shape.index(n, h, w, c);
        self.data[i] = v;
    }

    /// Contiguous channel vector at `[n, h, w, :]`.
    #[inline(always)]
    pub fn channels(&self, n: usize, h: usize, w: usize) -> &[T] {
        let start = self.shape.index(n, h, w, 0);
        &self.data[start..start + self.shape.c]
    }

    /// Contiguous row span `[n, h, w..w+pixels, :]` — `pixels * c` elements
    /// (NHWC rows are contiguous along w). The conv engines use this to
    /// stream a kernel row's worth of activations in one slice.
    #[inline(always)]
    pub fn row_span(&self, n: usize, h: usize, w: usize, pixels: usize) -> &[T] {
        debug_assert!(w + pixels <= self.shape.w, "row span out of bounds");
        let start = self.shape.index(n, h, w, 0);
        &self.data[start..start + pixels * self.shape.c]
    }

    /// Map element-wise into a new tensor.
    pub fn map<U: Copy + Default>(&self, f: impl Fn(T) -> U) -> Tensor4<U> {
        Tensor4 {
            shape: self.shape,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }
}

impl Tensor4<u8> {
    /// Random activation tensor with values in `[0, 2^bits)`.
    pub fn random_activations(shape: Shape4, bits: u32, rng: &mut Rng) -> Self {
        assert!(bits >= 1 && bits <= 8);
        let hi = (1i64 << bits) - 1;
        Self {
            shape,
            data: (0..shape.len()).map(|_| rng.range_i64(0, hi) as u8).collect(),
        }
    }
}

impl Tensor4<i8> {
    /// Random symmetric weight tensor with values in `[-2^(bits-1)+1, 2^(bits-1)-1]`
    /// (symmetric range, as in symmetric per-tensor quantization).
    pub fn random_weights(shape: Shape4, bits: u32, rng: &mut Rng) -> Self {
        assert!(bits >= 2 && bits <= 8);
        let hi = (1i64 << (bits - 1)) - 1;
        Self {
            shape,
            data: (0..shape.len())
                .map(|_| rng.range_i64(-hi, hi) as i8)
                .collect(),
        }
    }
}

impl Tensor4<f32> {
    /// Random float tensor, uniform in `[lo, hi)`.
    pub fn random_f32(shape: Shape4, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        Self {
            shape,
            data: (0..shape.len()).map(|_| rng.f32_range(lo, hi)).collect(),
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for Tensor4<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor4{} ", self.shape)?;
        if self.data.len() <= 32 {
            write!(f, "{:?}", self.data)
        } else {
            write!(f, "[{:?}, ... {} elements]", &self.data[..8], self.data.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_set_get() {
        let mut t = Tensor4::<i32>::zeros(Shape4::new(1, 2, 2, 3));
        assert_eq!(t.get(0, 1, 1, 2), 0);
        t.set(0, 1, 1, 2, 42);
        assert_eq!(t.get(0, 1, 1, 2), 42);
        assert_eq!(t.data().iter().sum::<i32>(), 42);
    }

    #[test]
    fn from_fn_index_agreement() {
        let t = Tensor4::from_fn(Shape4::new(2, 2, 2, 2), |n, h, w, c| {
            (n * 1000 + h * 100 + w * 10 + c) as i32
        });
        assert_eq!(t.get(1, 0, 1, 1), 1011);
        assert_eq!(t.get(0, 1, 0, 0), 100);
    }

    #[test]
    fn channels_slice_contiguous() {
        let t = Tensor4::from_fn(Shape4::new(1, 2, 2, 4), |_, h, w, c| {
            (h * 100 + w * 10 + c) as i32
        });
        assert_eq!(t.channels(0, 1, 1), &[110, 111, 112, 113]);
    }

    #[test]
    fn random_activations_in_range() {
        let mut rng = Rng::new(3);
        for bits in 1..=8u32 {
            let t = Tensor4::random_activations(Shape4::new(1, 4, 4, 4), bits, &mut rng);
            assert!(t.data().iter().all(|&v| (v as u32) < (1 << bits)));
        }
    }

    #[test]
    fn random_weights_symmetric_range() {
        let mut rng = Rng::new(5);
        let t = Tensor4::random_weights(Shape4::new(4, 3, 3, 2), 4, &mut rng);
        assert!(t.data().iter().all(|&v| (-7..=7).contains(&v)));
    }

    #[test]
    fn map_preserves_shape() {
        let t = Tensor4::from_fn(Shape4::new(1, 2, 2, 1), |_, h, w, _| (h + w) as i32);
        let u = t.map(|x| x as f32 * 0.5);
        assert_eq!(u.shape(), t.shape());
        assert_eq!(u.get(0, 1, 1, 0), 1.0);
    }

    #[test]
    #[should_panic]
    fn from_vec_rejects_bad_length() {
        Tensor4::from_vec(Shape4::new(1, 2, 2, 2), vec![0i32; 7]);
    }
}
