//! Tensor operations shared by the conv engines: padding, im2col, pooling
//! and activation helpers.

use super::{Shape4, Tensor4};

/// Padding mode for convolutions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    /// No padding; output shrinks by `k - 1`.
    Valid,
    /// Zero-pad so output spatial size equals input (stride 1).
    Same,
}

/// Zero-pad an NHWC activation tensor by `(py, px)` on each side.
pub fn pad_nhwc(x: &Tensor4<u8>, py: usize, px: usize) -> Tensor4<u8> {
    if py == 0 && px == 0 {
        return x.clone();
    }
    let s = x.shape();
    let out_shape = Shape4::new(s.n, s.h + 2 * py, s.w + 2 * px, s.c);
    let mut out = Tensor4::zeros(out_shape);
    for n in 0..s.n {
        for h in 0..s.h {
            for w in 0..s.w {
                for c in 0..s.c {
                    out.set(n, h + py, w + px, c, x.get(n, h, w, c));
                }
            }
        }
    }
    out
}

/// im2col: unfold receptive fields into rows of a matrix.
/// Input `[n,h,w,c]`, kernel `kh × kw`, stride `(sy,sx)` →
/// output `(n*oh*ow) × (kh*kw*c)`, row-major.
/// Returned as `(rows, cols, data)`.
pub fn im2col(
    x: &Tensor4<u8>,
    kh: usize,
    kw: usize,
    sy: usize,
    sx: usize,
) -> (usize, usize, Vec<u8>) {
    let s = x.shape();
    let (oh, ow) = s.conv_out(kh, kw, sy, sx);
    let rows = s.n * oh * ow;
    let cols = kh * kw * s.c;
    let mut data = Vec::with_capacity(rows * cols);
    for n in 0..s.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for ky in 0..kh {
                    for kx in 0..kw {
                        let row = x.channels(n, oy * sy + ky, ox * sx + kx);
                        data.extend_from_slice(row);
                    }
                }
            }
        }
    }
    (rows, cols, data)
}

/// 2×2 max pooling with stride 2 over an i32 NHWC tensor. Odd trailing
/// rows/columns are dropped (floor semantics), matching the JAX model.
pub fn max_pool2d(x: &Tensor4<i32>) -> Tensor4<i32> {
    max_pool2d_k(x, 2)
}

/// `k`×`k` max pooling with stride `k` over an i32 NHWC tensor. Trailing
/// rows/columns that don't fill a window are dropped (floor semantics);
/// `k = 2` is bit-identical to [`max_pool2d`].
///
/// The floor behavior is an explicit, tested contract of this function —
/// dropped cells never influence any output. Callers that consider
/// truncation a declaration error must reject it *before* pooling:
/// `model::NetworkSpec::validate` does exactly that for pool stages that
/// did not opt in via `floor = true`.
pub fn max_pool2d_k(x: &Tensor4<i32>, k: usize) -> Tensor4<i32> {
    assert!(k >= 1, "pool window must be >= 1");
    let s = x.shape();
    let oh = s.h / k;
    let ow = s.w / k;
    let mut out = Tensor4::zeros(Shape4::new(s.n, oh, ow, s.c));
    for n in 0..s.n {
        for y in 0..oh {
            for w in 0..ow {
                for c in 0..s.c {
                    let mut m = i32::MIN;
                    for dy in 0..k {
                        for dx in 0..k {
                            m = m.max(x.get(n, k * y + dy, k * w + dx, c));
                        }
                    }
                    out.set(n, y, w, c, m);
                }
            }
        }
    }
    out
}

/// ReLU on an i32 tensor (in place).
pub fn relu_i32(x: &mut Tensor4<i32>) {
    for v in x.data_mut() {
        if *v < 0 {
            *v = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn pad_centers_data() {
        let x = Tensor4::from_fn(Shape4::new(1, 2, 2, 1), |_, h, w, _| (1 + h * 2 + w) as u8);
        let p = pad_nhwc(&x, 1, 1);
        assert_eq!(p.shape(), Shape4::new(1, 4, 4, 1));
        assert_eq!(p.get(0, 0, 0, 0), 0);
        assert_eq!(p.get(0, 1, 1, 0), 1);
        assert_eq!(p.get(0, 2, 2, 0), 4);
        assert_eq!(p.get(0, 3, 3, 0), 0);
    }

    #[test]
    fn pad_zero_is_identity() {
        let mut rng = Rng::new(1);
        let x = Tensor4::random_activations(Shape4::new(2, 3, 3, 2), 4, &mut rng);
        assert_eq!(pad_nhwc(&x, 0, 0), x);
    }

    #[test]
    fn im2col_small_example() {
        // 1x3x3x1 input, 2x2 kernel, stride 1 -> 4 rows x 4 cols
        let x = Tensor4::from_fn(Shape4::new(1, 3, 3, 1), |_, h, w, _| (h * 3 + w) as u8);
        let (rows, cols, data) = im2col(&x, 2, 2, 1, 1);
        assert_eq!((rows, cols), (4, 4));
        // first RF: positions (0,0),(0,1),(1,0),(1,1) -> 0,1,3,4
        assert_eq!(&data[0..4], &[0, 1, 3, 4]);
        // last RF: (1,1),(1,2),(2,1),(2,2) -> 4,5,7,8
        assert_eq!(&data[12..16], &[4, 5, 7, 8]);
    }

    #[test]
    fn im2col_respects_stride() {
        let x = Tensor4::from_fn(Shape4::new(1, 4, 4, 1), |_, h, w, _| (h * 4 + w) as u8);
        let (rows, cols, data) = im2col(&x, 2, 2, 2, 2);
        assert_eq!((rows, cols), (4, 4));
        assert_eq!(&data[0..4], &[0, 1, 4, 5]);
        assert_eq!(&data[4..8], &[2, 3, 6, 7]);
    }

    #[test]
    fn max_pool_picks_max() {
        let x = Tensor4::from_fn(Shape4::new(1, 4, 4, 1), |_, h, w, _| (h * 4 + w) as i32);
        let p = max_pool2d(&x);
        assert_eq!(p.shape(), Shape4::new(1, 2, 2, 1));
        assert_eq!(p.get(0, 0, 0, 0), 5);
        assert_eq!(p.get(0, 1, 1, 0), 15);
    }

    #[test]
    fn max_pool_k_generalizes_2x2() {
        let mut rng = Rng::new(9);
        let x = Tensor4::random_activations(Shape4::new(2, 7, 7, 3), 4, &mut rng).map(|v| v as i32);
        // k=2 is bit-identical to the fixed 2x2 path
        assert_eq!(max_pool2d_k(&x, 2), max_pool2d(&x));
        // k=3 windows take the max of all nine cells
        let p = max_pool2d_k(&x, 3);
        assert_eq!(p.shape(), Shape4::new(2, 2, 2, 3));
        let mut m = i32::MIN;
        for dy in 0..3 {
            for dx in 0..3 {
                m = m.max(x.get(0, dy, dx, 0));
            }
        }
        assert_eq!(p.get(0, 0, 0, 0), m);
        // k=1 is the identity on whole windows
        assert_eq!(max_pool2d_k(&x, 1), x);
    }

    #[test]
    fn max_pool_drops_odd_edge() {
        let x = Tensor4::<i32>::zeros(Shape4::new(1, 5, 5, 2));
        assert_eq!(max_pool2d(&x).shape(), Shape4::new(1, 2, 2, 2));
    }

    #[test]
    fn max_pool_floor_boundary_pinned() {
        // The floor contract, value-level: trailing rows/cols that do not
        // fill a window are DROPPED and can never influence any output —
        // even when they hold the global maximum.
        let mut x = Tensor4::<i32>::zeros(Shape4::new(1, 5, 5, 1));
        x.set(0, 4, 4, 0, 1_000_000); // in the dropped edge
        x.set(0, 0, 4, 0, 1_000_000); // dropped trailing column
        x.set(0, 4, 0, 0, 1_000_000); // dropped trailing row
        x.set(0, 1, 1, 0, 7);
        let p = max_pool2d_k(&x, 2);
        assert_eq!(p.shape(), Shape4::new(1, 2, 2, 1));
        assert_eq!(p.get(0, 0, 0, 0), 7);
        assert!(p.data().iter().all(|&v| v <= 7), "dropped cells leaked: {p:?}");
        // and a k=3 window on a 7x7 map keeps exactly floor(7/3) = 2 rows
        let y = Tensor4::from_fn(Shape4::new(1, 7, 7, 1), |_, h, w, _| (h * 7 + w) as i32);
        let q = max_pool2d_k(&y, 3);
        assert_eq!(q.shape(), Shape4::new(1, 2, 2, 1));
        // window rows 3..6, cols 3..6 -> max at (5,5) = 40
        assert_eq!(q.get(0, 1, 1, 0), 40);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut x = Tensor4::from_vec(
            Shape4::new(1, 1, 2, 2),
            vec![-3, 0, 5, -1],
        );
        relu_i32(&mut x);
        assert_eq!(x.data(), &[0, 0, 5, 0]);
    }
}
