//! Minimal integer/float tensor substrate for the PCILT engines.
//!
//! Layout convention throughout the crate is **NHWC** for activations
//! (`[batch, height, width, channels]`) and **OHWI** for filters
//! (`[out_ch, kh, kw, in_ch]`) — chosen so the innermost loop of every conv
//! engine walks contiguous channel vectors.

mod shape;
mod tensor4;
mod ops;

pub use ops::{im2col, max_pool2d, max_pool2d_k, pad_nhwc, relu_i32, Padding};
pub use shape::Shape4;
pub use tensor4::Tensor4;
