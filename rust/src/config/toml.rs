//! Hand-rolled parser for the TOML subset this project uses for its config
//! files (serde/toml crates are unavailable in the offline build).
//!
//! Supported: `[section]` and `[section.sub]` headers, `[[name]]`
//! array-of-tables headers (each occurrence opens table `name.N`, so
//! `[[models]]` entries parse to `models.0.*`, `models.1.*`, …) including
//! nested arrays (`[[models.layers]]` appends to the last `[[models]]`
//! entry, parsing to `models.N.layers.M.*`),
//! `key = value` pairs with string / integer / float / boolean /
//! homogeneous-array values, `#` comments, and blank lines. Unsupported
//! TOML (multi-line strings, dates, inline tables) is rejected with a
//! line-numbered error — better a loud failure than silent
//! misconfiguration.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse error with 1-based line number.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn err<T>(line: usize, msg: impl Into<String>) -> Result<T, ParseError> {
    Err(ParseError {
        line,
        msg: msg.into(),
    })
}

/// A parsed document: dotted-path → value. Section `[a.b]` with `k = v`
/// stores under key `"a.b.k"`; top-level keys store as `"k"`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Document {
    entries: BTreeMap<String, Value>,
    /// `[[name]]` occurrence counts: `arrays["models"] == 2` after two
    /// `[[models]]` headers (whose keys live under `models.0.*` and
    /// `models.1.*`).
    arrays: BTreeMap<String, usize>,
}

impl Document {
    pub fn parse(text: &str) -> Result<Document, ParseError> {
        let mut doc = Document::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("[[") {
                let Some(name) = rest.strip_suffix("]]") else {
                    return err(lineno, "unterminated array-of-tables header");
                };
                let name = name.trim();
                let parts: Vec<&str> = name.split('.').collect();
                if parts.iter().any(|p| {
                    p.is_empty()
                        || !p
                            .chars()
                            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
                }) {
                    return err(lineno, format!("invalid array-of-tables name '{name}'"));
                }
                // TOML semantics for nested arrays-of-tables: every
                // intermediate segment must name an already-open array and
                // refers to its LAST element, so `[[models.layers]]`
                // appends to the layer list of the most recent
                // `[[models]]` entry (keys land under `models.N.layers.M`).
                let mut resolved = String::new();
                for (pi, part) in parts.iter().enumerate() {
                    if !resolved.is_empty() {
                        resolved.push('.');
                    }
                    resolved.push_str(part);
                    if pi + 1 < parts.len() {
                        match doc.arrays.get(&resolved) {
                            Some(&n) if n > 0 => {
                                resolved.push('.');
                                resolved.push_str(&(n - 1).to_string());
                            }
                            _ => {
                                return err(
                                    lineno,
                                    format!(
                                        "[[{name}]]: '{part}' is not a previously declared \
                                         [[...]] array"
                                    ),
                                )
                            }
                        }
                    }
                }
                let n = doc.arrays.entry(resolved.clone()).or_insert(0);
                let idx = *n;
                *n += 1;
                section = format!("{resolved}.{idx}");
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    return err(lineno, "unterminated section header");
                };
                let name = name.trim();
                if name.is_empty()
                    || !name
                        .chars()
                        .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '.' || c == '-')
                {
                    return err(lineno, format!("invalid section name '{name}'"));
                }
                section = name.to_string();
                continue;
            }
            let Some(eq) = line.find('=') else {
                return err(lineno, "expected 'key = value'");
            };
            let key = line[..eq].trim();
            if key.is_empty()
                || !key
                    .chars()
                    .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
            {
                return err(lineno, format!("invalid key '{key}'"));
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let path = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            if doc.entries.insert(path.clone(), value).is_some() {
                return err(lineno, format!("duplicate key '{path}'"));
            }
        }
        Ok(doc)
    }

    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    pub fn get_int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }

    pub fn get_float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }

    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// Number of `[[name]]` tables parsed (0 when none appeared).
    pub fn array_len(&self, name: &str) -> usize {
        self.arrays.get(name).copied().unwrap_or(0)
    }

    /// All keys, sorted (BTreeMap order).
    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.keys().map(String::as_str)
    }

    /// Keys under a section prefix (e.g. `"server"` matches `"server.port"`).
    pub fn section_keys<'a>(&'a self, prefix: &'a str) -> impl Iterator<Item = &'a str> + 'a {
        let dotted = format!("{prefix}.");
        self.entries
            .keys()
            .filter(move |k| k.starts_with(&dotted))
            .map(String::as_str)
    }
}

impl fmt::Display for Document {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (k, v) in &self.entries {
            writeln!(f, "{k} = {v:?}")?;
        }
        Ok(())
    }
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, line: usize) -> Result<Value, ParseError> {
    if text.is_empty() {
        return err(line, "missing value");
    }
    // String
    if let Some(rest) = text.strip_prefix('"') {
        let Some(inner) = rest.strip_suffix('"') else {
            return err(line, "unterminated string");
        };
        if inner.contains('"') {
            return err(line, "embedded quote in string (escapes unsupported)");
        }
        return Ok(Value::Str(inner.to_string()));
    }
    // Array
    if let Some(rest) = text.strip_prefix('[') {
        let Some(inner) = rest.strip_suffix(']') else {
            return err(line, "unterminated array");
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_array_items(inner) {
            items.push(parse_value(part.trim(), line)?);
        }
        return Ok(Value::Array(items));
    }
    // Bool
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Numbers (underscore separators allowed, as in TOML)
    let num = text.replace('_', "");
    if num.contains('.') || num.contains('e') || num.contains('E') {
        if let Ok(f) = num.parse::<f64>() {
            return Ok(Value::Float(f));
        }
    } else if let Ok(i) = num.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    err(line, format!("cannot parse value '{text}'"))
}

/// Split array items at top-level commas (strings may contain commas).
fn split_array_items(s: &str) -> Vec<&str> {
    let mut items = Vec::new();
    let mut start = 0;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            ',' if !in_str => {
                items.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    items.push(&s[start..]);
    items
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_scalars() {
        let doc = Document::parse(
            r#"
# top comment
name = "demo"
[server]
port = 8080
rate = 1.5
debug = true
[server.batch]
max = 32
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("name"), Some("demo"));
        assert_eq!(doc.get_int("server.port"), Some(8080));
        assert_eq!(doc.get_float("server.rate"), Some(1.5));
        assert_eq!(doc.get_bool("server.debug"), Some(true));
        assert_eq!(doc.get_int("server.batch.max"), Some(32));
    }

    #[test]
    fn parses_arrays() {
        let doc = Document::parse(r#"dims = [1, 2, 3]
names = ["a", "b,c"]"#).unwrap();
        let dims: Vec<i64> = doc
            .get("dims")
            .unwrap()
            .as_array()
            .unwrap()
            .iter()
            .map(|v| v.as_int().unwrap())
            .collect();
        assert_eq!(dims, vec![1, 2, 3]);
        let names = doc.get("names").unwrap().as_array().unwrap();
        assert_eq!(names[1].as_str(), Some("b,c"));
    }

    #[test]
    fn comments_inside_strings_survive() {
        let doc = Document::parse(r##"s = "a#b" # trailing"##).unwrap();
        assert_eq!(doc.get_str("s"), Some("a#b"));
    }

    #[test]
    fn underscore_numbers() {
        let doc = Document::parse("big = 1_000_000").unwrap();
        assert_eq!(doc.get_int("big"), Some(1_000_000));
    }

    #[test]
    fn negative_and_float_numbers() {
        let doc = Document::parse("a = -42\nb = -0.5\nc = 1e3").unwrap();
        assert_eq!(doc.get_int("a"), Some(-42));
        assert_eq!(doc.get_float("b"), Some(-0.5));
        assert_eq!(doc.get_float("c"), Some(1000.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Document::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Document::parse("x = \"unterminated").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        let e = Document::parse("a = 1\na = 2").unwrap_err();
        assert!(e.msg.contains("duplicate"));
    }

    #[test]
    fn int_as_float_coercion() {
        let doc = Document::parse("x = 3").unwrap();
        assert_eq!(doc.get_float("x"), Some(3.0));
    }

    #[test]
    fn section_keys_enumeration() {
        let doc = Document::parse("[s]\na = 1\nb = 2\n[t]\nc = 3").unwrap();
        let keys: Vec<&str> = doc.section_keys("s").collect();
        assert_eq!(keys, vec!["s.a", "s.b"]);
    }

    #[test]
    fn array_of_tables_index_each_occurrence() {
        let doc = Document::parse(
            r#"
[serve]
workers = 2
[[models]]
name = "a"
seed = 1
[[models]]
name = "b"
[other]
x = 1
"#,
        )
        .unwrap();
        assert_eq!(doc.array_len("models"), 2);
        assert_eq!(doc.array_len("nothing"), 0);
        assert_eq!(doc.get_str("models.0.name"), Some("a"));
        assert_eq!(doc.get_int("models.0.seed"), Some(1));
        assert_eq!(doc.get_str("models.1.name"), Some("b"));
        assert_eq!(doc.get_int("serve.workers"), Some(2));
        assert_eq!(doc.get_int("other.x"), Some(1));
    }

    #[test]
    fn bad_array_of_tables_headers_rejected() {
        assert!(Document::parse("[[models]\nname = \"a\"").is_err());
        // a dotted header whose parent array was never declared
        assert!(Document::parse("[[bad.name]]\nx = 1").is_err());
        assert!(Document::parse("[[]]\nx = 1").is_err());
        assert!(Document::parse("[[a..b]]\nx = 1").is_err());
    }

    #[test]
    fn nested_array_of_tables_attach_to_last_parent() {
        let doc = Document::parse(
            r#"
[[models]]
name = "a"
[[models.layers]]
type = "conv"
out_ch = 8
[[models.layers]]
type = "dense"
[[models]]
name = "b"
[[models.layers]]
type = "conv"
out_ch = 4
"#,
        )
        .unwrap();
        assert_eq!(doc.array_len("models"), 2);
        assert_eq!(doc.array_len("models.0.layers"), 2);
        assert_eq!(doc.array_len("models.1.layers"), 1);
        assert_eq!(doc.get_str("models.0.layers.0.type"), Some("conv"));
        assert_eq!(doc.get_int("models.0.layers.0.out_ch"), Some(8));
        assert_eq!(doc.get_str("models.0.layers.1.type"), Some("dense"));
        assert_eq!(doc.get_int("models.1.layers.0.out_ch"), Some(4));
        // layers before any [[models]] entry are a loud error
        assert!(Document::parse("[[models.layers]]\ntype = \"conv\"").is_err());
    }
}
