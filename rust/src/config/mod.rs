//! Typed configuration for the serving coordinator, ASIC simulator and
//! network descriptions, loaded from a TOML-subset file (see [`toml`]).
//!
//! Everything has defaults so `pcilt serve` runs with no config file; a file
//! overrides selectively. Unknown keys are rejected to catch typos.

pub mod toml;

use std::path::Path;

use crate::model::network::StageSpec;
use crate::model::EngineChoice;
use crate::pcilt::memory::NetworkSpec;
use crate::pcilt::planner::PlannerPolicy;

pub use self::toml::{Document, ParseError, Value};

/// Which convolution engine the coordinator routes requests to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Direct-multiplication baseline.
    Dm,
    /// Basic PCILT lookup (Figs 1–2).
    Pcilt,
    /// Segment-offset PCILT (Figs 5–6).
    Segment,
    /// Shared-table PCILT.
    Shared,
    /// AOT-compiled HLO artifact executed via PJRT.
    Hlo,
    /// Planner-selected per layer (see `pcilt::planner`).
    Auto,
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<EngineKind> {
        Some(match s {
            "dm" => EngineKind::Dm,
            "pcilt" => EngineKind::Pcilt,
            "segment" => EngineKind::Segment,
            "shared" => EngineKind::Shared,
            "hlo" => EngineKind::Hlo,
            "auto" => EngineKind::Auto,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            EngineKind::Dm => "dm",
            EngineKind::Pcilt => "pcilt",
            EngineKind::Segment => "segment",
            EngineKind::Shared => "shared",
            EngineKind::Hlo => "hlo",
            EngineKind::Auto => "auto",
        }
    }
}

/// `[planner]` section: cost-model weights and execution knobs for the
/// engine auto-selection planner (`pcilt::planner`).
#[derive(Debug, Clone, PartialEq)]
pub struct PlannerConfig {
    /// "analytic" (cost model) or "calibrate" (micro-benchmark winners).
    pub mode: PlannerMode,
    /// Batch-parallel worker threads inside one inference batch
    /// (0 = auto-detect).
    pub threads: usize,
    /// Fast-memory budget for lookup tables, in KiB.
    pub cache_kb: usize,
    /// Relative op energies for the analytic score.
    pub mult_cost: f64,
    pub add_cost: f64,
    pub fetch_cost: f64,
    /// Invocations one table build amortizes over.
    pub amortize: f64,
    /// Allow float-datapath baselines (Winograd/FFT) to win.
    pub allow_approximate: bool,
}

/// Planner scoring mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlannerMode {
    Analytic,
    Calibrate,
}

/// `[tables]` section: lifecycle knobs for the process-wide
/// `pcilt::store::TableStore` (byte budget, persisted cache location).
#[derive(Debug, Clone, PartialEq)]
pub struct TablesConfig {
    /// LRU eviction budget for resident tables, in MiB. 0 = unlimited:
    /// the store retains every table for the process lifetime (that IS
    /// the cache). Long-running deployments whose weights change over
    /// time (periodic refresh, many distinct models) should set a budget
    /// so stale tables are evicted rather than accumulated.
    pub budget_mb: usize,
    /// Directory holding `tables.bin` + `tables.manifest`. Empty = default
    /// to `<artifact_dir>/table_cache`.
    pub cache_dir: String,
    /// Load the cache at startup and save it at shutdown, so a restarted
    /// server performs zero redundant table builds.
    pub persist: bool,
    /// Palette-pack table entries whose byte streams compress well
    /// (ternary/low-cardinality weights). Packing is exact — gathers stay
    /// bit-identical — so it is on by default; disable to trade memory for
    /// the one-time decode on first gather.
    pub pack: bool,
    /// Per-model residency budget, in MiB. 0 = no per-model cap (only the
    /// global `budget_mb` applies). With a cap, tables owned exclusively
    /// by an over-budget model are demoted to the cold tier first, so one
    /// table-hungry model cannot starve its co-tenants.
    pub per_model_budget_mb: usize,
}

impl Default for TablesConfig {
    fn default() -> Self {
        TablesConfig {
            budget_mb: 0,
            cache_dir: String::new(),
            persist: false,
            pack: true,
            per_model_budget_mb: 0,
        }
    }
}

impl TablesConfig {
    /// Budget in bytes for `TableStore::set_budget_bytes`.
    pub fn budget_bytes(&self) -> u64 {
        self.budget_mb as u64 * 1024 * 1024
    }

    /// Per-model budget in bytes for `TableStore::set_model_budget_bytes`.
    pub fn per_model_budget_bytes(&self) -> u64 {
        self.per_model_budget_mb as u64 * 1024 * 1024
    }

    /// The cache directory, defaulting under the artifact dir.
    pub fn resolve_cache_dir(&self, artifact_dir: &str) -> std::path::PathBuf {
        if self.cache_dir.is_empty() {
            Path::new(artifact_dir).join("table_cache")
        } else {
            std::path::PathBuf::from(&self.cache_dir)
        }
    }
}

/// `[net]` section: the socket serving tier (`pcilt serve --net`,
/// `pcilt loadtest` self-serve) — listen address, loop-shard count,
/// per-model in-flight budget, latency SLO, autoscaler bounds,
/// per-connection rate limit, idle timeout and shutdown drain window.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetConfig {
    /// Listen address (`host:port`; port 0 picks an ephemeral port).
    pub addr: String,
    /// Event-loop shard threads (`pcilt-net-0..n-1`); the acceptor hands
    /// each new connection to the least-loaded shard.
    pub loops: usize,
    /// Admission control: per-model budget of admitted-but-unanswered
    /// requests. Beyond it, clients get explicit `Overloaded` frames.
    pub max_inflight: usize,
    /// Latency SLO in milliseconds; the batcher's deadline is derived
    /// from it (`net::slo_batch_deadline`) so batches close before the
    /// oldest request busts the SLO.
    pub slo_ms: u64,
    /// Graceful-drain window on shutdown, milliseconds.
    pub drain_ms: u64,
    /// Close quiescent connections after this many milliseconds. Zero is
    /// rejected (it would reap every connection on its first tick).
    pub idle_timeout_ms: u64,
    /// Autoscaler floor: the scaler never parks a pool below this many
    /// workers. Only meaningful when `max_workers` enables autoscaling.
    pub min_workers: usize,
    /// Autoscaler ceiling; 0 disables autoscaling (fixed pools sized by
    /// the top-level `workers` key).
    pub max_workers: usize,
    /// Per-connection token-bucket rate limit in requests/second (burst
    /// capacity is 2× the rate); 0 disables the limit.
    pub conn_rate_limit: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            addr: "127.0.0.1:7070".to_string(),
            loops: 1,
            max_inflight: 64,
            slo_ms: 50,
            drain_ms: 500,
            idle_timeout_ms: 30_000,
            min_workers: 1,
            max_workers: 0,
            conn_rate_limit: 0,
        }
    }
}

impl Default for PlannerConfig {
    fn default() -> Self {
        let p = PlannerPolicy::default();
        PlannerConfig {
            mode: PlannerMode::Analytic,
            threads: 0,
            cache_kb: (p.cache_bytes / 1024.0) as usize,
            mult_cost: p.mult_cost,
            add_cost: p.add_cost,
            fetch_cost: p.fetch_cost,
            amortize: p.amortize_invocations,
            allow_approximate: p.allow_approximate,
        }
    }
}

impl PlannerConfig {
    /// Convert to the planner's policy struct.
    pub fn to_policy(&self) -> PlannerPolicy {
        PlannerPolicy {
            mult_cost: self.mult_cost,
            add_cost: self.add_cost,
            fetch_cost: self.fetch_cost,
            cache_bytes: self.cache_kb as f64 * 1024.0,
            miss_penalty: PlannerPolicy::default().miss_penalty,
            amortize_invocations: self.amortize,
            page_in_cost: PlannerPolicy::default().page_in_cost,
            allow_approximate: self.allow_approximate,
        }
    }
}

/// One `[[models]]` entry: a named model the multi-model registry
/// (`coordinator::ModelRegistry`) loads into its own pool. All pools
/// borrow lookup tables from the single process `TableStore`, so models
/// sharing conv weights (shared backbones, fine-tuned heads) hold one
/// table copy between them.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    /// Routing name; requests carry it in their `model` field.
    pub name: String,
    /// Engine its pool serves with (`auto` = planner-selected). For
    /// layer-graph models this is the default for conv stages that don't
    /// declare their own `engine`.
    pub engine: EngineKind,
    /// Activation bit width for the seeded random source (ignored when
    /// `artifact_dir` is set — the bundle's own width wins).
    pub act_bits: u32,
    /// Weight seed for the random source. Models sharing a seed share a
    /// conv backbone — and therefore lookup tables.
    pub seed: u64,
    /// Re-randomize only the dense head from this seed: the
    /// "fine-tuned head over a shared backbone" variant.
    pub head_seed: Option<u64>,
    /// Load real weights from this artifact bundle instead of the seed.
    pub artifact_dir: Option<String>,
    /// Input image side for layer-graph models (`[[models.layers]]`).
    /// Ignored (fixed at the seed topology's 16) when `layers` is empty.
    pub img: usize,
    /// Arbitrary-depth layer graph, declared as `[[models.layers]]`
    /// entries. Empty = the paper's seed 2-conv topology. Validated at
    /// config-load time by `NetworkSpec` shape/dataflow propagation.
    pub layers: Vec<StageSpec>,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            name: String::new(),
            engine: EngineKind::Auto,
            act_bits: 4,
            seed: 42,
            head_seed: None,
            artifact_dir: None,
            img: 16,
            layers: Vec::new(),
        }
    }
}

impl ModelConfig {
    /// The layer-graph spec this model declares, when `layers` is
    /// non-empty.
    pub fn network_spec(&self) -> Option<crate::model::network::NetworkSpec> {
        if self.layers.is_empty() {
            return None;
        }
        Some(crate::model::network::NetworkSpec {
            act_bits: self.act_bits,
            img: self.img,
            in_ch: 1,
            stages: self.layers.clone(),
        })
    }
}

/// Serving coordinator configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Number of inference worker threads.
    pub workers: usize,
    /// Maximum dynamic batch size.
    pub max_batch: usize,
    /// Batching deadline: a partial batch is dispatched after this long.
    pub batch_deadline_us: u64,
    /// Bounded request-queue capacity (backpressure beyond this).
    pub queue_capacity: usize,
    /// Engine requests are routed to by default.
    pub engine: EngineKind,
    /// Directory holding `manifest.txt` + HLO artifacts.
    pub artifact_dir: String,
    /// Workload generator: mean request rate (requests/second).
    pub rate_rps: f64,
    /// Workload generator: total requests to issue.
    pub total_requests: usize,
    /// `[planner]` section (engine auto-selection).
    pub planner: PlannerConfig,
    /// `[tables]` section (table-store budget + persistence).
    pub tables: TablesConfig,
    /// `[net]` section (socket serving tier).
    pub net: NetConfig,
    /// `[[models]]` list: when non-empty, `pcilt serve` starts the
    /// multi-model registry instead of a single anonymous pool.
    pub models: Vec<ModelConfig>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 4,
            max_batch: 16,
            batch_deadline_us: 2_000,
            queue_capacity: 1024,
            engine: EngineKind::Pcilt,
            artifact_dir: "artifacts".to_string(),
            rate_rps: 500.0,
            total_requests: 2_000,
            planner: PlannerConfig::default(),
            tables: TablesConfig::default(),
            net: NetConfig::default(),
            models: Vec::new(),
        }
    }
}

/// Error produced by typed-config loading.
#[derive(Debug)]
pub enum ConfigError {
    Parse(ParseError),
    Io(std::io::Error),
    Invalid(String),
}

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ConfigError::Parse(e) => write!(f, "{e}"),
            ConfigError::Io(e) => write!(f, "io error reading config: {e}"),
            ConfigError::Invalid(msg) => write!(f, "invalid config: {msg}"),
        }
    }
}

impl std::error::Error for ConfigError {}

impl From<ParseError> for ConfigError {
    fn from(e: ParseError) -> ConfigError {
        ConfigError::Parse(e)
    }
}

impl From<std::io::Error> for ConfigError {
    fn from(e: std::io::Error) -> ConfigError {
        ConfigError::Io(e)
    }
}

fn invalid<T>(msg: impl Into<String>) -> Result<T, ConfigError> {
    Err(ConfigError::Invalid(msg.into()))
}

impl ServeConfig {
    /// Load from a TOML-subset file; missing keys keep defaults.
    pub fn load(path: &Path) -> Result<ServeConfig, ConfigError> {
        let text = std::fs::read_to_string(path)?;
        Self::from_document(&Document::parse(&text)?)
    }

    pub fn from_document(doc: &Document) -> Result<ServeConfig, ConfigError> {
        let mut cfg = ServeConfig::default();
        for key in doc.keys() {
            match key {
                "serve.workers" => {
                    cfg.workers = pos_usize(doc, key)?;
                }
                "serve.max_batch" => {
                    cfg.max_batch = pos_usize(doc, key)?;
                }
                "serve.batch_deadline_us" => {
                    cfg.batch_deadline_us = pos_usize(doc, key)? as u64;
                }
                "serve.queue_capacity" => {
                    cfg.queue_capacity = pos_usize(doc, key)?;
                }
                "serve.engine" => {
                    let s = doc.get_str(key).unwrap_or_default();
                    cfg.engine = EngineKind::parse(s)
                        .ok_or_else(|| ConfigError::Invalid(format!("unknown engine '{s}'")))?;
                }
                "serve.artifact_dir" => {
                    cfg.artifact_dir = doc
                        .get_str(key)
                        .ok_or_else(|| {
                            ConfigError::Invalid("artifact_dir must be a string".into())
                        })?
                        .to_string();
                }
                "serve.rate_rps" => {
                    let v = doc.get_float(key).unwrap_or(-1.0);
                    if v <= 0.0 {
                        return invalid("rate_rps must be > 0");
                    }
                    cfg.rate_rps = v;
                }
                "serve.total_requests" => {
                    cfg.total_requests = pos_usize(doc, key)?;
                }
                "planner.mode" => {
                    cfg.planner.mode = match doc.get_str(key) {
                        Some("analytic") => PlannerMode::Analytic,
                        Some("calibrate") => PlannerMode::Calibrate,
                        other => {
                            return invalid(format!(
                                "planner.mode must be analytic|calibrate, got {other:?}"
                            ))
                        }
                    };
                }
                "planner.threads" => {
                    // 0 is meaningful (= auto), so not pos_usize
                    cfg.planner.threads = match doc.get_int(key) {
                        Some(v) if v >= 0 => v as usize,
                        _ => return invalid("planner.threads must be >= 0"),
                    };
                }
                "planner.cache_kb" => {
                    cfg.planner.cache_kb = pos_usize(doc, key)?;
                }
                "planner.mult_cost" => {
                    cfg.planner.mult_cost = pos_float(doc, key)?;
                }
                "planner.add_cost" => {
                    cfg.planner.add_cost = pos_float(doc, key)?;
                }
                "planner.fetch_cost" => {
                    cfg.planner.fetch_cost = pos_float(doc, key)?;
                }
                "planner.amortize" => {
                    cfg.planner.amortize = pos_float(doc, key)?;
                }
                "planner.allow_approximate" => {
                    cfg.planner.allow_approximate = doc
                        .get_bool(key)
                        .ok_or_else(|| {
                            ConfigError::Invalid("planner.allow_approximate must be a bool".into())
                        })?;
                }
                "tables.budget_mb" => {
                    // 0 is meaningful (= unlimited), so not pos_usize
                    cfg.tables.budget_mb = match doc.get_int(key) {
                        Some(v) if v >= 0 => v as usize,
                        _ => return invalid("tables.budget_mb must be >= 0"),
                    };
                }
                "tables.cache_dir" => {
                    cfg.tables.cache_dir = doc
                        .get_str(key)
                        .ok_or_else(|| {
                            ConfigError::Invalid("tables.cache_dir must be a string".into())
                        })?
                        .to_string();
                }
                "tables.persist" => {
                    cfg.tables.persist = doc.get_bool(key).ok_or_else(|| {
                        ConfigError::Invalid("tables.persist must be a bool".into())
                    })?;
                }
                "tables.pack" => {
                    cfg.tables.pack = doc.get_bool(key).ok_or_else(|| {
                        ConfigError::Invalid("tables.pack must be a bool".into())
                    })?;
                }
                "tables.per_model_budget_mb" => {
                    // 0 is meaningful (= no per-model cap), so not pos_usize
                    cfg.tables.per_model_budget_mb = match doc.get_int(key) {
                        Some(v) if v >= 0 => v as usize,
                        _ => return invalid("tables.per_model_budget_mb must be >= 0"),
                    };
                }
                "net.addr" => {
                    let s = doc
                        .get_str(key)
                        .ok_or_else(|| ConfigError::Invalid("net.addr must be a string".into()))?;
                    if s.is_empty() {
                        return invalid("net.addr must be non-empty (host:port)");
                    }
                    cfg.net.addr = s.to_string();
                }
                "net.max_inflight" => {
                    cfg.net.max_inflight = pos_usize(doc, key)?;
                }
                "net.slo_ms" => {
                    cfg.net.slo_ms = pos_usize(doc, key)? as u64;
                }
                "net.drain_ms" => {
                    // 0 is meaningful (= close immediately on shutdown)
                    cfg.net.drain_ms = match doc.get_int(key) {
                        Some(v) if v >= 0 => v as u64,
                        _ => return invalid("net.drain_ms must be >= 0"),
                    };
                }
                "net.loops" => {
                    cfg.net.loops = pos_usize(doc, key)?;
                }
                "net.idle_timeout_ms" => {
                    // Zero would reap every connection on its first tick.
                    cfg.net.idle_timeout_ms = pos_usize(doc, key)? as u64;
                }
                "net.min_workers" => {
                    cfg.net.min_workers = pos_usize(doc, key)?;
                }
                "net.max_workers" => {
                    // 0 is meaningful (= autoscaling off)
                    cfg.net.max_workers = match doc.get_int(key) {
                        Some(v) if v >= 0 => v as usize,
                        _ => return invalid("net.max_workers must be >= 0"),
                    };
                }
                "net.conn_rate_limit" => {
                    // 0 is meaningful (= no per-connection limit)
                    cfg.net.conn_rate_limit = match doc.get_int(key) {
                        Some(v) if v >= 0 => v as u64,
                        _ => return invalid("net.conn_rate_limit must be >= 0"),
                    };
                }
                k if k.starts_with("network.") => {} // parsed by NetworkSpec
                k if k.starts_with("models.") => {}  // parsed by parse_models below
                k => return invalid(format!("unknown config key '{k}'")),
            }
        }
        cfg.models = parse_models(doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if self.max_batch > self.queue_capacity {
            return invalid(format!(
                "max_batch ({}) exceeds queue_capacity ({})",
                self.max_batch, self.queue_capacity
            ));
        }
        if self.workers == 0 || self.workers > 1024 {
            return invalid("workers must be in 1..=1024");
        }
        if !self.net.addr.contains(':') {
            return invalid(format!("net.addr '{}' must be host:port", self.net.addr));
        }
        if self.net.loops == 0 || self.net.loops > 64 {
            return invalid("net.loops must be in 1..=64");
        }
        if self.net.max_workers > 0 {
            if self.net.min_workers > self.net.max_workers {
                return invalid(format!(
                    "net.min_workers ({}) exceeds net.max_workers ({})",
                    self.net.min_workers, self.net.max_workers
                ));
            }
            if self.net.max_workers > 1024 {
                return invalid("net.max_workers must be <= 1024");
            }
        }
        let mut seen = std::collections::BTreeSet::new();
        for m in &self.models {
            if m.name.is_empty() {
                return invalid("every [[models]] entry needs a non-empty name");
            }
            if !seen.insert(m.name.as_str()) {
                return invalid(format!("duplicate model name '{}'", m.name));
            }
            if m.engine == EngineKind::Hlo && m.artifact_dir.is_none() {
                return invalid(format!(
                    "model '{}': engine \"hlo\" needs an artifact_dir",
                    m.name
                ));
            }
            if !m.layers.is_empty() {
                if m.engine == EngineKind::Hlo {
                    return invalid(format!(
                        "model '{}': a layers list cannot be served by the hlo engine",
                        m.name
                    ));
                }
                if m.artifact_dir.is_some() {
                    return invalid(format!(
                        "model '{}': layers use seeded weights; artifact_dir is not supported",
                        m.name
                    ));
                }
                // Shape/dataflow-validate the declared graph now — a bad
                // spec should fail at config load, not at pool boot.
                if let Some(spec) = m.network_spec() {
                    if let Err(e) = spec.validate() {
                        return invalid(format!("model '{}': {e}", m.name));
                    }
                }
            } else if m.img != 16 {
                return invalid(format!(
                    "model '{}': img is only configurable with a layers list",
                    m.name
                ));
            }
        }
        Ok(())
    }
}

/// Parse the `[[models]]` list (`models.N.*` keys after the array-of-tables
/// expansion in [`toml::Document`]).
fn parse_models(doc: &Document) -> Result<Vec<ModelConfig>, ConfigError> {
    let n = doc.array_len("models");
    // Loud failure for the single-vs-double-bracket typo: `[models]` (or a
    // stray `[models.N]` beyond the parsed array) produces `models.*` keys
    // that no `[[models]]` header claimed — silently ignoring them would
    // disable multi-model serving without a word.
    for key in doc.section_keys("models") {
        let rest = &key["models.".len()..];
        let idx_ok = rest
            .split_once('.')
            .and_then(|(idx, _)| idx.parse::<usize>().ok())
            .is_some_and(|idx| idx < n);
        if !idx_ok {
            return invalid(format!(
                "stray key '{key}': models must be declared as [[models]] entries \
                 (double brackets), not a [models] section"
            ));
        }
    }
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let prefix = format!("models.{i}.");
        let mut m = ModelConfig::default();
        for key in doc.section_keys(&format!("models.{i}")) {
            let field = &key[prefix.len()..];
            if field.starts_with("layers.") {
                continue; // parsed by parse_layers below
            }
            match field {
                "name" => {
                    m.name = doc
                        .get_str(key)
                        .ok_or_else(|| {
                            ConfigError::Invalid(format!("models[{i}].name must be a string"))
                        })?
                        .to_string();
                }
                "engine" => {
                    let s = doc.get_str(key).unwrap_or_default();
                    m.engine = EngineKind::parse(s).ok_or_else(|| {
                        ConfigError::Invalid(format!("models[{i}]: unknown engine '{s}'"))
                    })?;
                }
                "act_bits" => {
                    // u8 activation codes bound the model layer at 8 bits
                    // (NetworkSpec::validate enforces the same range).
                    m.act_bits = match doc.get_int(key) {
                        Some(v) if (1..=8).contains(&v) => v as u32,
                        _ => {
                            return invalid(format!("models[{i}].act_bits must be in 1..=8"))
                        }
                    };
                }
                "seed" => {
                    m.seed = match doc.get_int(key) {
                        Some(v) if v >= 0 => v as u64,
                        _ => return invalid(format!("models[{i}].seed must be >= 0")),
                    };
                }
                "head_seed" => {
                    m.head_seed = match doc.get_int(key) {
                        Some(v) if v >= 0 => Some(v as u64),
                        _ => return invalid(format!("models[{i}].head_seed must be >= 0")),
                    };
                }
                "artifact_dir" => {
                    m.artifact_dir = Some(
                        doc.get_str(key)
                            .ok_or_else(|| {
                                ConfigError::Invalid(format!(
                                    "models[{i}].artifact_dir must be a string"
                                ))
                            })?
                            .to_string(),
                    );
                }
                "img" => {
                    m.img = match doc.get_int(key) {
                        Some(v) if (1..=4096).contains(&v) => v as usize,
                        _ => return invalid(format!("models[{i}].img must be in 1..=4096")),
                    };
                }
                "layers" => {
                    return invalid(format!(
                        "models[{i}].layers must be declared as [[models.layers]] entries, \
                         not a scalar key"
                    ))
                }
                other => {
                    return invalid(format!("unknown [[models]] key '{other}' (entry {i})"))
                }
            }
        }
        if m.name.is_empty() {
            return invalid(format!("models[{i}] needs a name"));
        }
        // The model-level engine is the default for conv stages that don't
        // name their own (hlo + layers is rejected by validate()).
        let default_choice = match m.engine {
            EngineKind::Dm => EngineChoice::Dm,
            EngineKind::Pcilt => EngineChoice::Pcilt,
            EngineKind::Segment => EngineChoice::Segment { seg_n: 2 },
            EngineKind::Shared => EngineChoice::Shared,
            EngineKind::Auto | EngineKind::Hlo => EngineChoice::Auto,
        };
        m.layers = parse_layers(doc, i, default_choice)?;
        out.push(m);
    }
    Ok(out)
}

/// Parse one model's `[[models.layers]]` list (`models.N.layers.M.*` keys
/// after the nested array-of-tables expansion in [`toml::Document`]) into
/// typed [`StageSpec`]s. A conv entry may carry a `scale` key, which
/// desugars into a `Requantize` stage directly after it; a conv without an
/// `engine` key serves with `default` (the model-level engine).
fn parse_layers(
    doc: &Document,
    i: usize,
    default: EngineChoice,
) -> Result<Vec<StageSpec>, ConfigError> {
    let arr = format!("models.{i}.layers");
    let n = doc.array_len(&arr);
    let mut out = Vec::with_capacity(n);
    for j in 0..n {
        let prefix = format!("{arr}.{j}");
        let at = |field: &str| format!("{prefix}.{field}");
        let ty = doc.get_str(&at("type")).ok_or_else(|| {
            ConfigError::Invalid(format!(
                "models[{i}].layers[{j}] needs a type (conv|pool|requant|dense)"
            ))
        })?;
        let allowed: &[&str] = match ty {
            "conv" => &["type", "out_ch", "kernel", "stride", "engine", "seg_n", "scale"],
            "pool" => &["type", "k", "floor"],
            "requant" => &["type", "scale"],
            "dense" => &["type", "classes"],
            other => {
                return invalid(format!(
                    "models[{i}].layers[{j}]: unknown type '{other}' \
                     (expected conv|pool|requant|dense)"
                ))
            }
        };
        for key in doc.section_keys(&prefix) {
            let field = &key[prefix.len() + 1..];
            if !allowed.contains(&field) {
                return invalid(format!(
                    "models[{i}].layers[{j}]: unknown '{ty}' key '{field}'"
                ));
            }
        }
        let layer_int = |field: &str, default: i64, lo: i64, hi: i64| match doc.get(&at(field)) {
            None => Ok(default),
            Some(v) => v.as_int().filter(|x| (lo..=hi).contains(x)).ok_or_else(|| {
                ConfigError::Invalid(format!(
                    "models[{i}].layers[{j}].{field} must be an integer in {lo}..={hi}"
                ))
            }),
        };
        match ty {
            "conv" => {
                if doc.get(&at("out_ch")).is_none() {
                    return invalid(format!("models[{i}].layers[{j}]: conv needs out_ch"));
                }
                let out_ch = layer_int("out_ch", 0, 1, 4096)? as usize;
                let kernel = layer_int("kernel", 3, 1, 16)? as usize;
                let stride = layer_int("stride", 1, 1, 8)? as usize;
                let seg_n = layer_int("seg_n", 2, 1, 16)? as usize;
                let engine = match doc.get(&at("engine")) {
                    Some(v) => {
                        let s = v.as_str().ok_or_else(|| {
                            ConfigError::Invalid(format!(
                                "models[{i}].layers[{j}].engine must be a string"
                            ))
                        })?;
                        EngineChoice::parse(s, seg_n).ok_or_else(|| {
                            ConfigError::Invalid(format!(
                                "models[{i}].layers[{j}]: unknown engine '{s}' \
                                 (expected dm|pcilt|segment|shared|auto)"
                            ))
                        })?
                    }
                    None => match default {
                        EngineChoice::Segment { .. } => EngineChoice::Segment { seg_n },
                        other => other,
                    },
                };
                // seg_n on a non-segment conv would be silently ignored —
                // reject it like any other ineffective key.
                if doc.get(&at("seg_n")).is_some()
                    && !matches!(engine, EngineChoice::Segment { .. })
                {
                    return invalid(format!(
                        "models[{i}].layers[{j}]: seg_n only applies to engine = \"segment\""
                    ));
                }
                out.push(StageSpec::Conv {
                    out_ch,
                    kernel,
                    stride,
                    engine,
                });
                if doc.get(&at("scale")).is_some() {
                    out.push(StageSpec::Requantize {
                        scale: layer_scale(doc, &at("scale"), i, j)?,
                    });
                }
            }
            "pool" => {
                let k = layer_int("k", 2, 2, 16)? as usize;
                // `floor = true` opts into truncating (drop-trailing) pool
                // semantics; by default a non-tiling pool is rejected at
                // spec validation with a clear error.
                let floor = match doc.get(&at("floor")) {
                    None => false,
                    Some(v) => v.as_bool().ok_or_else(|| {
                        ConfigError::Invalid(format!(
                            "models[{i}].layers[{j}].floor must be a boolean"
                        ))
                    })?,
                };
                out.push(StageSpec::MaxPool { k, floor });
            }
            "requant" => {
                out.push(StageSpec::Requantize {
                    scale: layer_scale(doc, &at("scale"), i, j)?,
                });
            }
            "dense" => {
                if doc.get(&at("classes")).is_none() {
                    return invalid(format!("models[{i}].layers[{j}]: dense needs classes"));
                }
                let classes = layer_int("classes", 0, 2, 65536)? as usize;
                out.push(StageSpec::Dense { classes });
            }
            _ => unreachable!("type matched above"),
        }
    }
    Ok(out)
}

fn layer_scale(doc: &Document, key: &str, i: usize, j: usize) -> Result<f32, ConfigError> {
    match doc.get_float(key) {
        Some(v) if v > 0.0 && v.is_finite() => Ok(v as f32),
        _ => Err(ConfigError::Invalid(format!(
            "models[{i}].layers[{j}].scale must be a positive number"
        ))),
    }
}

fn pos_usize(doc: &Document, key: &str) -> Result<usize, ConfigError> {
    match doc.get_int(key) {
        Some(v) if v > 0 => Ok(v as usize),
        Some(v) => invalid(format!("{key} must be positive, got {v}")),
        None => invalid(format!("{key} must be an integer")),
    }
}

fn pos_float(doc: &Document, key: &str) -> Result<f64, ConfigError> {
    match doc.get_float(key) {
        Some(v) if v > 0.0 => Ok(v),
        Some(v) => invalid(format!("{key} must be positive, got {v}")),
        None => invalid(format!("{key} must be a number")),
    }
}

/// Parse a `[network]` section into a [`NetworkSpec`] (used by the memory
/// model and the `pcilt memory` CLI). Layout:
///
/// ```toml
/// [network]
/// filters = [50, 80, 120, 200, 350]
/// kernel = 5
/// weight_bits = 8
/// activation_bits = 8
/// input_channels = 3
/// ```
pub fn network_from_document(doc: &Document) -> Result<NetworkSpec, ConfigError> {
    let filters: Vec<usize> = match doc.get("network.filters") {
        Some(Value::Array(a)) => a
            .iter()
            .map(|v| {
                v.as_int()
                    .filter(|&i| i > 0)
                    .map(|i| i as usize)
                    .ok_or_else(|| ConfigError::Invalid("filters must be positive ints".into()))
            })
            .collect::<Result<_, _>>()?,
        _ => return invalid("network.filters must be an array"),
    };
    if filters.is_empty() {
        return invalid("network.filters must be non-empty");
    }
    let kernel = doc.get_int("network.kernel").unwrap_or(5);
    let weight_bits = doc.get_int("network.weight_bits").unwrap_or(8);
    let activation_bits = doc.get_int("network.activation_bits").unwrap_or(8);
    let input_channels = doc.get_int("network.input_channels").unwrap_or(3);
    for (name, v, lo, hi) in [
        ("kernel", kernel, 1, 16),
        ("weight_bits", weight_bits, 1, 32),
        ("activation_bits", activation_bits, 1, 16),
        ("input_channels", input_channels, 1, 4096),
    ] {
        if v < lo || v > hi {
            return invalid(format!("network.{name} must be in {lo}..={hi}, got {v}"));
        }
    }
    Ok(NetworkSpec {
        filters,
        kernel: kernel as usize,
        weight_bits: weight_bits as u32,
        activation_bits: activation_bits as u32,
        input_channels: input_channels as usize,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_valid() {
        ServeConfig::default().validate().unwrap();
    }

    #[test]
    fn overrides_apply() {
        let doc = Document::parse(
            r#"
[serve]
workers = 8
max_batch = 32
engine = "segment"
rate_rps = 100.0
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.max_batch, 32);
        assert_eq!(cfg.engine, EngineKind::Segment);
        assert_eq!(cfg.rate_rps, 100.0);
        // untouched default
        assert_eq!(cfg.queue_capacity, ServeConfig::default().queue_capacity);
    }

    #[test]
    fn unknown_key_rejected() {
        let doc = Document::parse("[serve]\ntypo_key = 1").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn planner_section_parses() {
        let doc = Document::parse(
            r#"
[serve]
engine = "auto"
[planner]
mode = "calibrate"
threads = 8
cache_kb = 1024
mult_cost = 5.0
amortize = 1000
allow_approximate = true
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.engine, EngineKind::Auto);
        assert_eq!(cfg.planner.mode, PlannerMode::Calibrate);
        assert_eq!(cfg.planner.threads, 8);
        assert_eq!(cfg.planner.cache_kb, 1024);
        assert_eq!(cfg.planner.mult_cost, 5.0);
        assert_eq!(cfg.planner.amortize, 1000.0);
        assert!(cfg.planner.allow_approximate);
        // untouched planner defaults survive
        assert_eq!(cfg.planner.add_cost, PlannerConfig::default().add_cost);
        let policy = cfg.planner.to_policy();
        assert_eq!(policy.cache_bytes, 1024.0 * 1024.0);
    }

    #[test]
    fn net_section_parses() {
        let doc = Document::parse(
            r#"
[net]
addr = "0.0.0.0:9000"
max_inflight = 128
slo_ms = 25
drain_ms = 0
loops = 4
min_workers = 2
max_workers = 8
conn_rate_limit = 500
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.net.addr, "0.0.0.0:9000");
        assert_eq!(cfg.net.max_inflight, 128);
        assert_eq!(cfg.net.slo_ms, 25);
        assert_eq!(cfg.net.drain_ms, 0, "0 = close immediately");
        assert_eq!(cfg.net.loops, 4);
        assert_eq!(cfg.net.min_workers, 2);
        assert_eq!(cfg.net.max_workers, 8);
        assert_eq!(cfg.net.conn_rate_limit, 500);
        // untouched defaults survive
        let d = NetConfig::default();
        assert_eq!(ServeConfig::default().net, d);
        assert_eq!(d.addr, "127.0.0.1:7070");
        assert_eq!(d.loops, 1);
        assert_eq!(d.max_workers, 0, "autoscaling is opt-in");
        assert_eq!(d.conn_rate_limit, 0, "rate limiting is opt-in");
    }

    #[test]
    fn net_section_rejects_bad_values() {
        for (toml, what) in [
            ("[net]\naddr = \"\"", "empty addr"),
            ("[net]\naddr = \"noport\"", "addr without port"),
            ("[net]\nmax_inflight = 0", "zero in-flight budget"),
            ("[net]\nslo_ms = 0", "zero SLO"),
            ("[net]\ndrain_ms = -1", "negative drain"),
            ("[net]\nloops = 0", "zero loop shards"),
            ("[net]\nloops = 65", "loop shards beyond cap"),
            ("[net]\nmin_workers = 0", "zero worker floor"),
            ("[net]\nmin_workers = 4\nmax_workers = 2", "floor above ceiling"),
            ("[net]\nconn_rate_limit = -1", "negative rate limit"),
            ("[net]\ntypo = 1", "unknown net key"),
        ] {
            let doc = Document::parse(toml).unwrap();
            assert!(ServeConfig::from_document(&doc).is_err(), "accepted {what}: {toml}");
        }
    }

    #[test]
    fn net_idle_timeout_ms_parses_and_threads() {
        // Regression (PR 10): `idle_timeout_ms` used to be missing from
        // NetConfig entirely, so `NetOpts::from_config` silently filled
        // the idle timeout from `..NetOpts::default()`.
        let doc = Document::parse("[net]\nidle_timeout_ms = 1234").unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.net.idle_timeout_ms, 1234);
        assert_eq!(NetConfig::default().idle_timeout_ms, 30_000);
        // Zero would reap every connection on its first tick.
        let doc = Document::parse("[net]\nidle_timeout_ms = 0").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err(), "zero idle timeout accepted");
        // Roundtrip into the resolved net options.
        let opts = crate::net::NetOpts::from_config(&cfg.net);
        assert_eq!(opts.idle_timeout, std::time::Duration::from_millis(1234));
    }

    #[test]
    fn tables_section_parses() {
        let doc = Document::parse(
            r#"
[tables]
budget_mb = 256
cache_dir = "/var/cache/pcilt"
persist = true
pack = false
per_model_budget_mb = 64
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.tables.budget_mb, 256);
        assert_eq!(cfg.tables.budget_bytes(), 256 * 1024 * 1024);
        assert_eq!(cfg.tables.cache_dir, "/var/cache/pcilt");
        assert!(cfg.tables.persist);
        assert!(!cfg.tables.pack);
        assert_eq!(cfg.tables.per_model_budget_mb, 64);
        assert_eq!(cfg.tables.per_model_budget_bytes(), 64 * 1024 * 1024);
        assert_eq!(
            cfg.tables.resolve_cache_dir("artifacts"),
            std::path::PathBuf::from("/var/cache/pcilt")
        );
    }

    #[test]
    fn tables_defaults_and_cache_dir_fallback() {
        let cfg = ServeConfig::default();
        assert_eq!(cfg.tables.budget_mb, 0, "default is unlimited");
        assert!(!cfg.tables.persist);
        assert!(cfg.tables.pack, "packing is on by default (exact, free wins)");
        assert_eq!(cfg.tables.per_model_budget_mb, 0, "no per-model cap by default");
        assert_eq!(
            cfg.tables.resolve_cache_dir("artifacts"),
            std::path::Path::new("artifacts").join("table_cache")
        );
    }

    #[test]
    fn tables_bad_values_rejected() {
        let doc = Document::parse("[tables]\nbudget_mb = -1").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        let doc = Document::parse("[tables]\npersist = 3").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        let doc = Document::parse("[tables]\npack = 1").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        let doc = Document::parse("[tables]\nper_model_budget_mb = -4").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn planner_bad_mode_rejected() {
        let doc = Document::parse("[planner]\nmode = \"guess\"").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        let doc = Document::parse("[planner]\nthreads = -1").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn invalid_engine_rejected() {
        let doc = Document::parse("[serve]\nengine = \"gpu\"").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn batch_larger_than_queue_rejected() {
        let doc = Document::parse("[serve]\nmax_batch = 100\nqueue_capacity = 10").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn network_spec_parses() {
        let doc = Document::parse(
            r#"
[network]
filters = [50, 80, 120, 200, 350]
kernel = 5
weight_bits = 8
activation_bits = 4
"#,
        )
        .unwrap();
        let net = network_from_document(&doc).unwrap();
        assert_eq!(net.filters, vec![50, 80, 120, 200, 350]);
        assert_eq!(net.activation_bits, 4);
        assert_eq!(net.input_channels, 3); // default
    }

    #[test]
    fn network_bad_bits_rejected() {
        let doc = Document::parse("[network]\nfilters = [4]\nweight_bits = 99").unwrap();
        assert!(network_from_document(&doc).is_err());
    }

    #[test]
    fn models_section_parses() {
        let doc = Document::parse(
            r#"
[serve]
workers = 2
[[models]]
name = "base"
engine = "pcilt"
act_bits = 4
seed = 7
[[models]]
name = "tuned"
engine = "auto"
seed = 7
head_seed = 99
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.models.len(), 2);
        assert_eq!(cfg.models[0].name, "base");
        assert_eq!(cfg.models[0].engine, EngineKind::Pcilt);
        assert_eq!(cfg.models[0].seed, 7);
        assert_eq!(cfg.models[0].head_seed, None);
        assert_eq!(cfg.models[1].name, "tuned");
        assert_eq!(cfg.models[1].engine, EngineKind::Auto);
        assert_eq!(cfg.models[1].head_seed, Some(99));
        assert_eq!(cfg.workers, 2);
    }

    #[test]
    fn models_default_is_empty() {
        assert!(ServeConfig::default().models.is_empty());
        let doc = Document::parse("[serve]\nworkers = 3").unwrap();
        assert!(ServeConfig::from_document(&doc).unwrap().models.is_empty());
    }

    #[test]
    fn models_bad_entries_rejected() {
        // missing name
        let doc = Document::parse("[[models]]\nseed = 1").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // duplicate names
        let doc =
            Document::parse("[[models]]\nname = \"a\"\n[[models]]\nname = \"a\"").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // unknown key
        let doc = Document::parse("[[models]]\nname = \"a\"\ntypo = 1").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // bad engine
        let doc = Document::parse("[[models]]\nname = \"a\"\nengine = \"gpu\"").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // act_bits out of range
        let doc = Document::parse("[[models]]\nname = \"a\"\nact_bits = 99").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // hlo without artifacts
        let doc = Document::parse("[[models]]\nname = \"a\"\nengine = \"hlo\"").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn model_layers_parse_into_typed_stages() {
        let doc = Document::parse(
            r#"
[[models]]
name = "deep"
act_bits = 2
seed = 9
img = 20
[[models.layers]]
type = "conv"
out_ch = 8
kernel = 3
engine = "pcilt"
scale = 0.05
[[models.layers]]
type = "pool"
k = 2
[[models.layers]]
type = "conv"
out_ch = 4
kernel = 3
engine = "segment"
seg_n = 4
[[models.layers]]
type = "requant"
scale = 0.1
[[models.layers]]
type = "dense"
classes = 10
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert_eq!(cfg.models.len(), 1);
        let m = &cfg.models[0];
        assert_eq!(m.img, 20);
        // conv+scale desugars to conv followed by requantize
        assert_eq!(
            m.layers,
            vec![
                StageSpec::Conv {
                    out_ch: 8,
                    kernel: 3,
                    stride: 1,
                    engine: EngineChoice::Pcilt,
                },
                StageSpec::Requantize { scale: 0.05 },
                StageSpec::MaxPool { k: 2, floor: false },
                StageSpec::Conv {
                    out_ch: 4,
                    kernel: 3,
                    stride: 1,
                    engine: EngineChoice::Segment { seg_n: 4 },
                },
                StageSpec::Requantize { scale: 0.1 },
                StageSpec::Dense { classes: 10 },
            ]
        );
        let spec = m.network_spec().unwrap();
        spec.validate().unwrap();
        assert_eq!(spec.conv_count(), 2);
    }

    #[test]
    fn pool_floor_key_parses_and_defaults_strict() {
        let doc = Document::parse(
            r#"
[[models]]
name = "m"
act_bits = 2
img = 17
[[models.layers]]
type = "conv"
out_ch = 2
kernel = 4
scale = 0.1
[[models.layers]]
type = "pool"
k = 2
floor = true
[[models.layers]]
type = "dense"
classes = 4
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert!(matches!(
            cfg.models[0].layers[2],
            StageSpec::MaxPool { k: 2, floor: true }
        ));
        // A strict (default) pool that does not tile its map is a config
        // error at spec validation: conv k4 on 17 -> 17 - 4 + 1 = 14, and
        // 14 % 4 != 0, so a strict k4 pool does not tile.
        let doc = Document::parse(
            r#"
[[models]]
name = "m"
act_bits = 2
img = 17
[[models.layers]]
type = "conv"
out_ch = 2
kernel = 4
scale = 0.1
[[models.layers]]
type = "pool"
k = 4
[[models.layers]]
type = "dense"
classes = 4
"#,
        )
        .unwrap();
        let err = ServeConfig::from_document(&doc).unwrap_err();
        assert!(err.to_string().contains("does not tile"), "{err}");
        // non-boolean floor is rejected
        let doc = Document::parse(
            "[[models]]\nname = \"m\"\n[[models.layers]]\ntype = \"pool\"\nfloor = 3",
        )
        .unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn model_engine_is_the_default_for_unmarked_conv_layers() {
        let doc = Document::parse(
            r#"
[[models]]
name = "m"
engine = "dm"
act_bits = 2
[[models.layers]]
type = "conv"
out_ch = 4
scale = 0.1
[[models.layers]]
type = "conv"
out_ch = 4
engine = "pcilt"
scale = 0.1
[[models.layers]]
type = "dense"
classes = 4
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        let m = &cfg.models[0];
        assert!(matches!(
            m.layers[0],
            StageSpec::Conv { engine: EngineChoice::Dm, .. }
        ));
        assert!(matches!(
            m.layers[2],
            StageSpec::Conv { engine: EngineChoice::Pcilt, .. }
        ));
        // engine = "segment" inherits with the layer's own seg_n
        let doc = Document::parse(
            r#"
[[models]]
name = "m"
engine = "segment"
act_bits = 2
[[models.layers]]
type = "conv"
out_ch = 4
seg_n = 4
scale = 0.1
[[models.layers]]
type = "dense"
classes = 4
"#,
        )
        .unwrap();
        let cfg = ServeConfig::from_document(&doc).unwrap();
        assert!(matches!(
            cfg.models[0].layers[0],
            StageSpec::Conv { engine: EngineChoice::Segment { seg_n: 4 }, .. }
        ));
    }

    #[test]
    fn bad_model_layers_rejected() {
        let wrap = |layers: &str| {
            format!("[[models]]\nname = \"m\"\nact_bits = 2\n{layers}")
        };
        // unknown type
        let doc = Document::parse(&wrap("[[models.layers]]\ntype = \"relu\"")).unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // conv without out_ch
        let doc = Document::parse(&wrap("[[models.layers]]\ntype = \"conv\"")).unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // unknown key for the type
        let doc =
            Document::parse(&wrap("[[models.layers]]\ntype = \"pool\"\nout_ch = 4")).unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // unknown engine
        let doc = Document::parse(&wrap(
            "[[models.layers]]\ntype = \"conv\"\nout_ch = 4\nengine = \"gpu\"",
        ))
        .unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // scalar `layers` key instead of [[models.layers]]
        let doc = Document::parse("[[models]]\nname = \"m\"\nlayers = [1]").unwrap();
        let err = ServeConfig::from_document(&doc).unwrap_err();
        assert!(err.to_string().contains("[[models.layers]]"), "{err}");
        // shape/dataflow-invalid graph fails at config load: missing
        // requantize between conv and dense
        let doc = Document::parse(&wrap(
            "[[models.layers]]\ntype = \"conv\"\nout_ch = 4\n\
             [[models.layers]]\ntype = \"dense\"\nclasses = 4",
        ))
        .unwrap();
        let err = ServeConfig::from_document(&doc).unwrap_err();
        assert!(err.to_string().contains("requantize"), "{err}");
        // img without a layers list
        let doc = Document::parse("[[models]]\nname = \"m\"\nimg = 32").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
        // seg_n on a non-segment conv is ineffective -> loud error
        let doc = Document::parse(&wrap(
            "[[models.layers]]\ntype = \"conv\"\nout_ch = 4\nengine = \"pcilt\"\n\
             seg_n = 4\nscale = 0.1\n[[models.layers]]\ntype = \"dense\"\nclasses = 4",
        ))
        .unwrap();
        let err = ServeConfig::from_document(&doc).unwrap_err();
        assert!(err.to_string().contains("seg_n"), "{err}");
        // a forced segment whose offset space overflows act_bits dies at
        // config load via NetworkSpec validation (act_bits 2 x seg_n 16)
        let doc = Document::parse(&wrap(
            "[[models.layers]]\ntype = \"conv\"\nout_ch = 4\nengine = \"segment\"\n\
             seg_n = 16\nscale = 0.1\n[[models.layers]]\ntype = \"dense\"\nclasses = 4",
        ))
        .unwrap();
        let err = ServeConfig::from_document(&doc).unwrap_err();
        assert!(err.to_string().contains("offset space"), "{err}");
        // layers cannot be combined with an artifact bundle
        let doc = Document::parse(&wrap(
            "artifact_dir = \"x\"\n[[models.layers]]\ntype = \"conv\"\nout_ch = 4\n\
             scale = 0.1\n[[models.layers]]\ntype = \"dense\"\nclasses = 4",
        ))
        .unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn single_bracket_models_section_is_a_loud_error() {
        // `[models]` instead of `[[models]]` must fail, not silently
        // disable multi-model serving.
        let doc = Document::parse("[models]\nname = \"a\"").unwrap();
        let err = ServeConfig::from_document(&doc).unwrap_err();
        assert!(err.to_string().contains("[[models]]"), "{err}");
        // stray indexed section beyond the declared entries too
        let doc =
            Document::parse("[[models]]\nname = \"a\"\n[models.5]\nseed = 1").unwrap();
        assert!(ServeConfig::from_document(&doc).is_err());
    }

    #[test]
    fn engine_name_roundtrip() {
        for e in [
            EngineKind::Dm,
            EngineKind::Pcilt,
            EngineKind::Segment,
            EngineKind::Shared,
            EngineKind::Hlo,
            EngineKind::Auto,
        ] {
            assert_eq!(EngineKind::parse(e.name()), Some(e));
        }
    }
}
