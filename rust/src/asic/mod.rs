//! ASIC simulator substrate — the hardware the paper's performance claims
//! presuppose, built as a transparent cost/cycle model (DESIGN.md §2).
//!
//! * [`cost`] — per-operation energy/latency/area, calibrated to the Dally
//!   NIPS'15 numbers the paper cites.
//! * [`units`] — cycle-stepped memory banks and the Fig 4 adder tree.
//! * [`engines`] — PCILT / DM / segment / Winograd / FFT datapath models.
//! * [`report`] — comparison tables for E2/E3.

pub mod cost;
pub mod engines;
pub mod report;
pub mod units;

pub use engines::{
    simulate_dm, simulate_fft, simulate_pcilt, simulate_segment, simulate_winograd, AsicReport,
    LayerWorkload, TableMem,
};
