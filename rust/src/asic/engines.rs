//! ASIC datapath configurations for the four convolution algorithms the
//! paper discusses — PCILT (Fig 3), DM, Winograd/Toom-Cook and FFT — over
//! one conv-layer workload. Experiment E2.
//!
//! Each model charges per-operation costs from [`super::cost`] and derives
//! cycles from the unit pipeline models. The Winograd/FFT entries include
//! the paper's "much more complex circuitry" as explicit area and control
//! overheads, making the claimed crossover (simpler algorithm wins on a
//! highly optimized ASIC) inspectable and disputable.

use super::cost::{
    add_cost, mul_cost, reg_cost, rom_read_cost, shift_cost, sram_read_cost, NumKind, UnitCost,
};
use super::units::AdderTree;

/// One conv layer's workload for the simulator.
#[derive(Debug, Clone, Copy)]
pub struct LayerWorkload {
    pub h: usize,
    pub w: usize,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub act_bits: u32,
    pub weight_bits: u32,
}

impl LayerWorkload {
    pub fn positions(&self) -> usize {
        self.k * self.k * self.cin
    }

    pub fn rf_count(&self) -> u64 {
        ((self.h - self.k + 1) * (self.w - self.k + 1)) as u64
    }

    /// Accumulator (product) width.
    pub fn product_bits(&self) -> u32 {
        self.weight_bits + self.act_bits
    }

    /// A small paper-flavoured default: 5×5 filter over a feature map.
    pub fn default_small() -> LayerWorkload {
        LayerWorkload {
            h: 64,
            w: 64,
            cin: 8,
            cout: 16,
            k: 5,
            act_bits: 4,
            weight_bits: 8,
        }
    }
}

/// Where tables live (the paper: SRAM for flexibility, ROM once frozen).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TableMem {
    Sram,
    Rom,
}

/// Simulation report for one engine on one workload.
#[derive(Debug, Clone)]
pub struct AsicReport {
    pub engine: String,
    pub cycles: u64,
    pub energy_pj: f64,
    pub area_um2: f64,
    /// Ops breakdown for the report tables.
    pub mults: u64,
    pub adds: u64,
    pub mem_reads: u64,
    pub lanes: usize,
}

impl AsicReport {
    /// Inferences (RF outputs) per second at a clock, per this datapath.
    pub fn throughput(&self, wl: &LayerWorkload, clock_ghz: f64) -> f64 {
        let outputs = wl.rf_count() as f64 * wl.cout as f64;
        outputs / (self.cycles as f64 / (clock_ghz * 1e9))
    }

    /// Energy per output in pJ.
    pub fn energy_per_output(&self, wl: &LayerWorkload) -> f64 {
        self.energy_pj / (wl.rf_count() as f64 * wl.cout as f64)
    }
}

fn charge(c: UnitCost, n: u64, energy: &mut f64) {
    *energy += c.energy_pj * n as f64;
}

/// PCILT ASIC (Fig 3): per output channel, a lane holds its PCILT bank next
/// to an adder tree. Per RF contribution: activation fetch (shared act
/// buffer) → table fetch → adder tree. No multipliers on the die.
pub fn simulate_pcilt(
    wl: &LayerWorkload,
    lanes: usize,
    tree_width: usize,
    mem: TableMem,
) -> AsicReport {
    let positions = wl.positions() as u64;
    let rfs = wl.rf_count();
    let outputs = rfs * wl.cout as u64;
    // Fig 3: each PCILT is its own small memory block with its own address
    // and data buses, "situated next to the results adder" — a fetch pays
    // for a 2^act_bits-entry block, not a monolithic bank. Total per-lane
    // table capacity is still `positions` such blocks (area below).
    let block_bytes = (1u64 << wl.act_bits) as f64 * wl.product_bits() as f64 / 8.0;
    let bank_bytes = positions as f64 * block_bytes;
    let table_cost = match mem {
        TableMem::Sram => sram_read_cost(block_bytes),
        TableMem::Rom => rom_read_cost(block_bytes),
    };
    let table_area = match mem {
        TableMem::Sram => sram_read_cost(bank_bytes).area_um2,
        TableMem::Rom => rom_read_cost(bank_bytes).area_um2,
    };
    // Activation buffer: one RF row of the input feature map.
    let act_buf_bytes = (wl.w * wl.cin) as f64 * wl.act_bits as f64 / 8.0 * wl.k as f64;
    let act_cost = sram_read_cost(act_buf_bytes);
    let acc_bits = wl.product_bits() + 8; // headroom for the accumulation

    // Cycles: each output needs `positions` table fetches reduced through
    // the tree; a lane processes one output at a time; fetch and reduce are
    // pipelined so the tree feed rate dominates.
    let per_output_cycles = AdderTree::reduce_cycles(tree_width, positions as usize);
    let serial_outputs = outputs.div_ceil(lanes as u64);
    let cycles = serial_outputs * per_output_cycles;

    let mut energy = 0.0;
    // Activation fetches: shared across output channels (fetched once per
    // RF position, broadcast to lanes).
    charge(act_cost, rfs * positions, &mut energy);
    // Table fetches: one per (output, position).
    charge(table_cost, outputs * positions, &mut energy);
    // Adds: positions-1 per output plus accumulator folds (≈ positions).
    charge(add_cost(acc_bits, NumKind::Int), outputs * positions, &mut energy);
    // Offset registers.
    charge(reg_cost(), outputs * positions, &mut energy);

    // Area: per lane, the filter's table blocks + tree of adders; shared
    // act buffer.
    let adders_per_lane = (2 * tree_width - 1) as f64;
    let area = lanes as f64
        * (table_area + adders_per_lane * add_cost(acc_bits, NumKind::Int).area_um2)
        + act_cost.area_um2;

    AsicReport {
        engine: format!("pcilt(tree={tree_width},{mem:?})"),
        cycles,
        energy_pj: energy,
        area_um2: area,
        mults: 0,
        adds: outputs * positions,
        mem_reads: outputs * positions + rfs * positions,
        lanes,
    }
}

/// DM ASIC: MAC lanes (multiplier + adder) fed by weight and activation
/// buffers.
pub fn simulate_dm(wl: &LayerWorkload, lanes: usize) -> AsicReport {
    let positions = wl.positions() as u64;
    let rfs = wl.rf_count();
    let outputs = rfs * wl.cout as u64;
    let macs = outputs * positions;
    // Weight buffer: per-lane SRAM holding one filter (the lane's current
    // output channel), refilled from the layer store between channels —
    // symmetric with the PCILT lane's local table blocks.
    let weight_bytes = positions as f64 * wl.weight_bits as f64 / 8.0;
    let w_cost = sram_read_cost(weight_bytes);
    let act_buf_bytes = (wl.w * wl.cin) as f64 * wl.act_bits as f64 / 8.0 * wl.k as f64;
    let act_cost = sram_read_cost(act_buf_bytes);
    let acc_bits = wl.product_bits() + 8;

    // One MAC per lane per cycle (II=1, multiplier pipelined).
    let cycles = macs.div_ceil(lanes as u64)
        + mul_cost(wl.weight_bits.max(wl.act_bits), NumKind::Int).latency_cycles as u64;

    let mut energy = 0.0;
    charge(act_cost, rfs * positions, &mut energy);
    charge(w_cost, macs, &mut energy); // weight fetch per MAC
    charge(mul_cost(wl.weight_bits.max(wl.act_bits), NumKind::Int), macs, &mut energy);
    charge(add_cost(acc_bits, NumKind::Int), macs, &mut energy);

    let area = lanes as f64
        * (mul_cost(wl.weight_bits.max(wl.act_bits), NumKind::Int).area_um2
            + add_cost(acc_bits, NumKind::Int).area_um2
            + w_cost.area_um2)
        + act_cost.area_um2;

    AsicReport {
        engine: "dm".into(),
        cycles,
        energy_pj: energy,
        area_um2: area,
        mults: macs,
        adds: macs,
        mem_reads: macs + rfs * positions,
        lanes,
    }
}

/// Segment-offset PCILT ASIC (Figs 5–6): shift/mask pre-processing packs
/// `seg_n` activations into an offset; one (larger) table fetch per segment.
pub fn simulate_segment(
    wl: &LayerWorkload,
    lanes: usize,
    seg_n: usize,
    mem: TableMem,
) -> AsicReport {
    let positions = wl.positions() as u64;
    let rfs = wl.rf_count();
    let outputs = rfs * wl.cout as u64;
    let n_segments = (wl.positions()).div_ceil(seg_n) as u64;
    let seg_rows = 1u64 << (seg_n as u32 * wl.act_bits);
    let value_bits = wl.product_bits() + (seg_n as f64).log2().ceil() as u32;
    // One block per segment, each with its own buses (as in Fig 6).
    let block_bytes = seg_rows as f64 * value_bits as f64 / 8.0;
    let bank_bytes = n_segments as f64 * block_bytes;
    let table_cost = match mem {
        TableMem::Sram => sram_read_cost(block_bytes),
        TableMem::Rom => rom_read_cost(block_bytes),
    };
    let table_area = match mem {
        TableMem::Sram => sram_read_cost(bank_bytes).area_um2,
        TableMem::Rom => rom_read_cost(bank_bytes).area_um2,
    };
    let act_buf_bytes = (wl.w * wl.cin) as f64 * wl.act_bits as f64 / 8.0 * wl.k as f64;
    let act_cost = sram_read_cost(act_buf_bytes);
    let acc_bits = value_bits + 8;

    // The pre-processing pipeline runs ahead of the fetch/add pipeline
    // ("pipelining the results to the convolutional circuitry. Thus, the
    // overhead due to it can be minimal") — offsets are shared across
    // output channels, so the lane-limited fetch/reduce dominates:
    let per_output_cycles = AdderTree::reduce_cycles(
        // tree matched to segment count per RF
        (n_segments as usize).min(8).max(1),
        n_segments as usize,
    );
    let cycles = outputs.div_ceil(lanes as u64) * per_output_cycles;

    let mut energy = 0.0;
    charge(act_cost, rfs * positions, &mut energy); // still read every act
    charge(shift_cost(wl.act_bits), rfs * positions, &mut energy); // pack
    charge(table_cost, outputs * n_segments, &mut energy);
    charge(add_cost(acc_bits, NumKind::Int), outputs * n_segments, &mut energy);

    let area = lanes as f64
        * (table_area + 8.0 * add_cost(acc_bits, NumKind::Int).area_um2
            + (positions as f64 / seg_n as f64) * shift_cost(wl.act_bits).area_um2)
        + act_cost.area_um2;

    AsicReport {
        engine: format!("segment(n={seg_n},{mem:?})"),
        cycles,
        energy_pj: energy,
        area_um2: area,
        mults: 0,
        adds: outputs * n_segments,
        mem_reads: outputs * n_segments + rfs * positions,
        lanes,
    }
}

/// Winograd F(2×2,3×3) ASIC: 2.25× fewer multiplies but transform adders
/// and control add circuitry; only defined for k=3 workloads.
pub fn simulate_winograd(wl: &LayerWorkload, lanes: usize) -> AsicReport {
    assert_eq!(wl.k, 3, "winograd datapath models 3x3 kernels");
    let tiles = (((wl.h - 2).div_ceil(2)) * ((wl.w - 2).div_ceil(2))) as u64;
    let pairs = (wl.cin * wl.cout) as u64;
    let mults = tiles * pairs * 16;
    // transforms (see WinogradEngine::op_counts)
    let adds = tiles * (wl.cin as u64 * 32 + wl.cout as u64 * 24 + pairs * 16);
    // Wider datapath: products of transformed values need more bits
    let mul_bits = wl.product_bits() + 4;
    let acc_bits = mul_bits + 8;

    let cycles = (mults + adds / 4).div_ceil(lanes as u64) + 8; // transform pipeline depth
    let weight_bytes = (wl.cout as u64 * 16 * wl.cin as u64) as f64 * mul_bits as f64 / 8.0;
    let w_cost = sram_read_cost(weight_bytes.max(1024.0));
    let act_buf_bytes = (wl.w * wl.cin * 4) as f64 * wl.act_bits as f64 / 8.0;
    let act_cost = sram_read_cost(act_buf_bytes);

    let mut energy = 0.0;
    charge(act_cost, tiles * wl.cin as u64 * 16, &mut energy);
    charge(w_cost, mults, &mut energy);
    charge(mul_cost(mul_bits, NumKind::Int), mults, &mut energy);
    charge(add_cost(acc_bits, NumKind::Int), adds, &mut energy);

    // Complexity overhead: transform networks + control ≈ 40% extra area
    // over the MAC array (the paper's "much more complex circuitry").
    let mac_area = lanes as f64
        * (mul_cost(mul_bits, NumKind::Int).area_um2 + add_cost(acc_bits, NumKind::Int).area_um2);
    let area = mac_area * 1.4 + w_cost.area_um2 + act_cost.area_um2;

    AsicReport {
        engine: "winograd".into(),
        cycles,
        energy_pj: energy,
        area_um2: area,
        mults,
        adds,
        mem_reads: mults + tiles * wl.cin as u64 * 16,
        lanes,
    }
}

/// FFT ASIC: complex butterflies in wide fixed point / float; the paper's
/// "theoretically faster but much more complex" comparator.
pub fn simulate_fft(wl: &LayerWorkload, lanes: usize) -> AsicReport {
    let fh = wl.h.next_power_of_two() as u64;
    let fw = wl.w.next_power_of_two() as u64;
    let pts = fh * fw;
    let lg = (pts as f64).log2() as u64;
    let ffts = (wl.cin + wl.cout) as u64; // fwd per in-ch + inv per out-ch
    let butterflies = ffts * pts / 2 * lg;
    let pointwise = (wl.cin * wl.cout) as u64 * pts;
    // Complex mult = 4 real mults + 2 adds; butterfly adds = 4.
    let mults = butterflies * 4 + pointwise * 4;
    let adds = butterflies * 6 + pointwise * 2;

    let cycles = (mults).div_ceil(lanes as u64) + 16; // deep FFT pipeline
    let spec_bytes = pts as f64 * 8.0; // complex f32 spectrum buffer
    let mem = sram_read_cost(spec_bytes);

    let mut energy = 0.0;
    charge(mem, butterflies * 2 + pointwise * 2, &mut energy);
    charge(mul_cost(32, NumKind::Float), mults, &mut energy);
    charge(add_cost(32, NumKind::Float), adds, &mut energy);

    let mac_area = lanes as f64
        * (mul_cost(32, NumKind::Float).area_um2 + add_cost(32, NumKind::Float).area_um2);
    // Twiddle ROMs, bit-reversal networks, complex datapath: 60% overhead.
    let area = mac_area * 1.6 + mem.area_um2 * 2.0;

    AsicReport {
        engine: "fft".into(),
        cycles,
        energy_pj: energy,
        area_um2: area,
        mults,
        adds,
        mem_reads: butterflies * 2 + pointwise * 2,
        lanes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl() -> LayerWorkload {
        LayerWorkload::default_small()
    }

    #[test]
    fn pcilt_has_no_multipliers() {
        let r = simulate_pcilt(&wl(), 16, 8, TableMem::Sram);
        assert_eq!(r.mults, 0);
        assert!(r.adds > 0);
    }

    #[test]
    fn pcilt_beats_dm_on_energy_per_output() {
        // The paper's central ASIC claim at equal lane count.
        let w = wl();
        let p = simulate_pcilt(&w, 16, 8, TableMem::Sram);
        let d = simulate_dm(&w, 16);
        assert!(
            p.energy_per_output(&w) < d.energy_per_output(&w),
            "pcilt={} dm={}",
            p.energy_per_output(&w),
            d.energy_per_output(&w)
        );
    }

    #[test]
    fn pcilt_lane_is_smaller_than_dm_lane_at_low_cardinality() {
        // "the on-chip area of an ASIC can house more such units than
        // standard ALUs" — holds in the regime the paper claims for itself
        // ("appropriate in CNNs that use activations with small
        // cardinality"): boolean activations, modest adder tree. At INT8
        // activations the table blocks outgrow a multiplier and the claim
        // flips — bench_asic sweeps this crossover (E2).
        let w = LayerWorkload {
            act_bits: 1,
            ..wl()
        };
        let p = simulate_pcilt(&w, 1, 2, TableMem::Rom);
        let d = simulate_dm(&w, 1);
        assert!(p.area_um2 < d.area_um2, "pcilt={} dm={}", p.area_um2, d.area_um2);
        // and the flip at high cardinality:
        let w8 = LayerWorkload {
            act_bits: 8,
            ..wl()
        };
        let p8 = simulate_pcilt(&w8, 1, 2, TableMem::Rom);
        let d8 = simulate_dm(&w8, 1);
        assert!(p8.area_um2 > d8.area_um2);
    }

    #[test]
    fn segment_reduces_cycles_vs_basic_pcilt() {
        let w = LayerWorkload {
            act_bits: 1,
            ..wl()
        };
        let basic = simulate_pcilt(&w, 16, 8, TableMem::Sram);
        let seg = simulate_segment(&w, 16, 8, TableMem::Sram);
        assert!(
            seg.cycles * 2 < basic.cycles,
            "segment={} basic={}",
            seg.cycles,
            basic.cycles
        );
    }

    #[test]
    fn rom_cheaper_than_sram_tables() {
        let w = wl();
        let s = simulate_pcilt(&w, 16, 8, TableMem::Sram);
        let r = simulate_pcilt(&w, 16, 8, TableMem::Rom);
        assert!(r.energy_pj < s.energy_pj);
        assert!(r.area_um2 < s.area_um2);
        assert_eq!(r.cycles, s.cycles);
    }

    #[test]
    fn fft_needs_more_area_and_energy_on_small_kernels() {
        // "will need much more complex (and larger on-chip) circuitry"
        let w = wl();
        let p = simulate_pcilt(&w, 16, 8, TableMem::Sram);
        let f = simulate_fft(&w, 16);
        assert!(f.area_um2 > p.area_um2);
        assert!(f.energy_pj > p.energy_pj);
    }

    #[test]
    fn winograd_cuts_mults_but_not_below_pcilt() {
        let w = LayerWorkload { k: 3, ..wl() };
        let d = simulate_dm(&w, 16);
        let win = simulate_winograd(&w, 16);
        let p = simulate_pcilt(&w, 16, 8, TableMem::Sram);
        assert!(win.mults < d.mults);
        assert_eq!(p.mults, 0);
    }

    #[test]
    fn throughput_scales_with_lanes() {
        let w = wl();
        let r16 = simulate_pcilt(&w, 16, 8, TableMem::Sram);
        let r64 = simulate_pcilt(&w, 64, 8, TableMem::Sram);
        let t16 = r16.throughput(&w, 1.0);
        let t64 = r64.throughput(&w, 1.0);
        assert!(t64 > t16 * 3.0, "t16={t16} t64={t64}");
    }

    #[test]
    fn higher_cardinality_raises_pcilt_table_energy() {
        let w4 = wl();
        let w8 = LayerWorkload {
            act_bits: 8,
            ..wl()
        };
        let r4 = simulate_pcilt(&w4, 16, 8, TableMem::Sram);
        let r8 = simulate_pcilt(&w8, 16, 8, TableMem::Sram);
        assert!(r8.energy_pj > r4.energy_pj);
    }
}
