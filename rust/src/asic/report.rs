//! Tabular reporting for the ASIC experiments (E2/E3): formats
//! [`AsicReport`]s into the comparison tables printed by `bench_asic` and
//! the `pcilt sim` CLI subcommand.

use crate::util::stats::{fmt_bytes, fmt_count};

use super::engines::{AsicReport, LayerWorkload};

/// A rendered comparison table.
pub struct ComparisonTable {
    pub title: String,
    pub rows: Vec<String>,
}

impl ComparisonTable {
    pub fn print(&self) {
        println!("\n## {}", self.title);
        for r in &self.rows {
            println!("{r}");
        }
    }
}

/// Build the engine-comparison table for one workload at a clock.
pub fn comparison_table(
    title: &str,
    wl: &LayerWorkload,
    reports: &[AsicReport],
    clock_ghz: f64,
) -> ComparisonTable {
    let mut rows = Vec::new();
    rows.push(format!(
        "workload: {}x{}x{} -> {} filters {}x{}, a{}w{} bits, {} lanes, {:.1} GHz",
        wl.h, wl.w, wl.cin, wl.cout, wl.k, wl.k, wl.act_bits, wl.weight_bits,
        reports.first().map(|r| r.lanes).unwrap_or(0), clock_ghz
    ));
    rows.push(format!(
        "{:<24} {:>12} {:>12} {:>12} {:>12} {:>14} {:>12}",
        "engine", "cycles", "mults", "adds", "energy/out", "throughput", "area"
    ));
    // Normalize against the first report (conventionally the DM baseline).
    let base = reports.first();
    for r in reports {
        let speedup = base
            .map(|b| b.cycles as f64 / r.cycles as f64)
            .unwrap_or(1.0);
        rows.push(format!(
            "{:<24} {:>12} {:>12} {:>12} {:>10.2}pJ {:>11.2e}/s {:>9} ({:>5.2}x vs base)",
            r.engine,
            fmt_count(r.cycles as u128),
            fmt_count(r.mults as u128),
            fmt_count(r.adds as u128),
            r.energy_per_output(wl),
            r.throughput(wl, clock_ghz),
            fmt_bytes(r.area_um2), // µm² rendered via byte formatter scale
            speedup,
        ));
    }
    ComparisonTable {
        title: title.to_string(),
        rows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asic::engines::{simulate_dm, simulate_pcilt, TableMem};

    #[test]
    fn table_renders_all_engines() {
        let wl = LayerWorkload::default_small();
        let reports = vec![
            simulate_dm(&wl, 16),
            simulate_pcilt(&wl, 16, 8, TableMem::Sram),
        ];
        let t = comparison_table("E2", &wl, &reports, 1.0);
        assert_eq!(t.rows.len(), 4); // header x2 + 2 engines
        assert!(t.rows[2].contains("dm"));
        assert!(t.rows[3].contains("pcilt"));
    }
}
