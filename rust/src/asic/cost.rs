//! Unit cost models for the ASIC simulator.
//!
//! The paper's performance claims live on a hypothetical custom CNN ASIC;
//! we make them testable with a transparent operation-level cost model.
//! Energy numbers are calibrated to the source the paper itself cites for
//! its "differs by more than a magnitude" claim — W. Dally, *High-
//! Performance Hardware for Machine Learning*, NIPS 2015 tutorial (45 nm):
//!
//! | op                | energy (pJ) |
//! |-------------------|-------------|
//! | INT8 add          | 0.03        |
//! | INT32 add         | 0.1         |
//! | FP32 add          | 0.9         |
//! | INT8 multiply     | 0.2         |
//! | INT32 multiply    | 3.1         |
//! | FP32 multiply     | 3.7         |
//! | SRAM read (8 KB)  | 5           |
//! | SRAM read (32 KB) | 10          |
//! | SRAM read (1 MB)  | 100         |
//! | DRAM read         | 1,280–2,560 |
//!
//! Latency is modeled in cycles with simple width-scaled rules; area in
//! arbitrary gate units scaled to Dally's add/multiply area ratios (INT8
//! add ≈ 36 µm², INT8 mul ≈ 282 µm², FP32 add 4,184 µm², FP32 mul
//! 7,700 µm² at 45 nm). The absolute numbers matter less than the
//! *ratios*, which are what the paper's argument uses.

/// Numeric kind of an operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NumKind {
    Int,
    Float,
}

/// Cost (energy pJ, latency cycles, area µm²) of one operator instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UnitCost {
    pub energy_pj: f64,
    pub latency_cycles: u32,
    pub area_um2: f64,
}

/// Interpolate/extrapolate energies by bit width from the calibration
/// anchors, linear in width for adds, quadratic for multiplies (array
/// multiplier scaling).
pub fn add_cost(bits: u32, kind: NumKind) -> UnitCost {
    match kind {
        NumKind::Int => {
            // anchors: 8 -> 0.03 pJ, 32 -> 0.1 pJ (linear in width)
            let energy = 0.03 + (bits.max(1) as f64 - 8.0) * (0.1 - 0.03) / 24.0;
            UnitCost {
                energy_pj: energy.max(0.005),
                latency_cycles: 1,
                area_um2: 36.0 * bits as f64 / 8.0,
            }
        }
        NumKind::Float => UnitCost {
            energy_pj: 0.9,
            latency_cycles: 2,
            area_um2: 4184.0,
        },
    }
}

pub fn mul_cost(bits: u32, kind: NumKind) -> UnitCost {
    match kind {
        NumKind::Int => {
            // anchors: 8 -> 0.2 pJ, 32 -> 3.1 pJ; power-law fit
            // e = 0.2 * w^alpha with alpha = ln(15.5)/ln(4) ≈ 1.977
            // (≈ quadratic, as expected for an array multiplier).
            let w = bits.max(1) as f64 / 8.0;
            let alpha = (3.1f64 / 0.2).ln() / 4f64.ln();
            let energy = 0.2 * w.powf(alpha);
            UnitCost {
                energy_pj: energy.max(0.02),
                latency_cycles: if bits <= 8 { 1 } else { 3 },
                area_um2: 282.0 * w * w,
            }
        }
        NumKind::Float => UnitCost {
            energy_pj: 3.7,
            latency_cycles: 4,
            area_um2: 7700.0,
        },
    }
}

/// SRAM read cost as a function of bank capacity in bytes.
/// Anchors: 8 KB → 5 pJ, 32 KB → 10 pJ, 1 MB → 100 pJ
/// (≈ energy ∝ sqrt(capacity), the usual bank-wire scaling).
pub fn sram_read_cost(capacity_bytes: f64) -> UnitCost {
    let kb = (capacity_bytes / 1024.0).max(0.03125); // floor at a 32 B block
    // fit e = a * sqrt(kb): through (8,5): a = 5/sqrt(8) = 1.77;
    // check: 32 KB -> 10.0 ✓, 1024 KB -> 56.6 (under the 100 anchor;
    // take the max of sqrt fit and linear-to-1MB fit for conservatism)
    let sqrt_fit = 5.0 / 8f64.sqrt() * kb.sqrt();
    let lin_fit = 100.0 * kb / 1024.0;
    UnitCost {
        energy_pj: sqrt_fit.max(lin_fit),
        latency_cycles: if kb <= 32.0 { 1 } else { 2 },
        // ~0.45 µm²/byte at 45nm 6T SRAM (~0.075 µm²/bit)
        area_um2: capacity_bytes * 0.45,
    }
}

/// ROM read: cheaper than SRAM of the same size (no write circuitry);
/// the paper notes PCILTs "can be stored in ROM instead of RAM".
pub fn rom_read_cost(capacity_bytes: f64) -> UnitCost {
    let s = sram_read_cost(capacity_bytes);
    UnitCost {
        energy_pj: s.energy_pj * 0.5,
        latency_cycles: s.latency_cycles,
        area_um2: s.area_um2 * 0.4,
    }
}

/// Off-chip DRAM read per 32-bit word.
pub fn dram_read_cost() -> UnitCost {
    UnitCost {
        energy_pj: 1920.0, // middle of Dally's 1.28–2.56 nJ range
        latency_cycles: 100,
        area_um2: 0.0,
    }
}

/// Register-file access (tiny, ~1 pJ at most): used for the shift/mask
/// offset pre-processing, which the paper notes is much cheaper than
/// arithmetic.
pub fn reg_cost() -> UnitCost {
    UnitCost {
        energy_pj: 0.01,
        latency_cycles: 0,
        area_um2: 10.0,
    }
}

/// Shift/mask op — "bit shifting and masking perform much better than
/// multiplication and division, or even addition and subtraction".
pub fn shift_cost(bits: u32) -> UnitCost {
    UnitCost {
        energy_pj: 0.01 * bits as f64 / 8.0,
        latency_cycles: 1,
        area_um2: 12.0 * bits as f64 / 8.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dally_anchor_points() {
        assert!((add_cost(8, NumKind::Int).energy_pj - 0.03).abs() < 1e-12);
        assert!((add_cost(32, NumKind::Int).energy_pj - 0.1).abs() < 1e-12);
        assert!((mul_cost(8, NumKind::Int).energy_pj - 0.2).abs() < 1e-12);
        assert!((mul_cost(32, NumKind::Int).energy_pj - 3.1).abs() < 1e-9);
        assert_eq!(add_cost(32, NumKind::Float).energy_pj, 0.9);
        assert_eq!(mul_cost(32, NumKind::Float).energy_pj, 3.7);
    }

    #[test]
    fn paper_ratio_claims_hold() {
        // Dally via the paper: FP32 vs INT8 — 30x for add, 18.5x for mul.
        let add_ratio =
            add_cost(32, NumKind::Float).energy_pj / add_cost(8, NumKind::Int).energy_pj;
        let mul_ratio =
            mul_cost(32, NumKind::Float).energy_pj / mul_cost(8, NumKind::Int).energy_pj;
        assert!((add_ratio - 30.0).abs() < 1.0, "add ratio {add_ratio}");
        assert!((mul_ratio - 18.5).abs() < 1.0, "mul ratio {mul_ratio}");
    }

    #[test]
    fn mul_much_pricier_than_add() {
        // The core PCILT premise: eliminating the multiply matters.
        for bits in [4, 8, 16, 32] {
            let mul = mul_cost(bits, NumKind::Int).energy_pj;
            let add = add_cost(bits, NumKind::Int).energy_pj;
            assert!(mul > 2.5 * add);
        }
    }

    #[test]
    fn sram_anchors() {
        assert!((sram_read_cost(8.0 * 1024.0).energy_pj - 5.0).abs() < 0.01);
        assert!((sram_read_cost(32.0 * 1024.0).energy_pj - 10.0).abs() < 0.01);
        assert!((sram_read_cost(1024.0 * 1024.0).energy_pj - 100.0).abs() < 0.01);
    }

    #[test]
    fn sram_monotone_in_capacity() {
        let mut last = 0.0;
        for kb in [1.0, 4.0, 16.0, 64.0, 256.0, 2048.0] {
            let e = sram_read_cost(kb * 1024.0).energy_pj;
            assert!(e > last);
            last = e;
        }
    }

    #[test]
    fn small_sram_cheaper_than_int32_dram() {
        // PCILT's case rests on small fast table memory beating repeated
        // arithmetic + big memory traffic.
        assert!(sram_read_cost(4096.0).energy_pj < dram_read_cost().energy_pj / 100.0);
    }

    #[test]
    fn rom_cheaper_than_sram() {
        let s = sram_read_cost(65536.0);
        let r = rom_read_cost(65536.0);
        assert!(r.energy_pj < s.energy_pj);
        assert!(r.area_um2 < s.area_um2);
    }

    #[test]
    fn shifts_are_nearly_free() {
        assert!(shift_cost(16).energy_pj < add_cost(8, NumKind::Int).energy_pj);
    }
}
