//! Cycle-stepped hardware unit models: SRAM banks with port contention and
//! the pipelined adder tree of Fig 4.
//!
//! These are deliberately small, explicit state machines — the experiments
//! step them cycle by cycle so bottleneck claims ("the inference speed
//! bottleneck there will be the adder") come out of a simulation rather
//! than a formula.

/// A memory bank with a fixed number of read ports. Requests beyond the
/// port count in a cycle stall (model of the shared-PCILT "sharing … may
/// cause a processing delay").
#[derive(Debug, Clone)]
pub struct MemBank {
    pub capacity_bytes: f64,
    pub ports: u32,
    /// Reads served this cycle (reset by `tick`).
    inflight: u32,
    /// Total reads served.
    pub reads: u64,
    /// Total cycles any request had to stall for a port.
    pub stalls: u64,
}

impl MemBank {
    pub fn new(capacity_bytes: f64, ports: u32) -> MemBank {
        assert!(ports >= 1);
        MemBank {
            capacity_bytes,
            ports,
            inflight: 0,
            reads: 0,
            stalls: 0,
        }
    }

    /// Try to issue a read this cycle. Returns false (and records a stall)
    /// if all ports are busy.
    pub fn try_read(&mut self) -> bool {
        if self.inflight < self.ports {
            self.inflight += 1;
            self.reads += 1;
            true
        } else {
            self.stalls += 1;
            false
        }
    }

    /// Advance one cycle.
    pub fn tick(&mut self) {
        self.inflight = 0;
    }
}

/// Pipelined adder tree (Fig 4): `width` leaf inputs per cycle, `depth =
/// ceil(log2(width))` register stages, plus a root accumulator. With
/// `width = 1` it degenerates to the single serial adder whose bottleneck
/// the paper calls out.
#[derive(Debug, Clone)]
pub struct AdderTree {
    pub width: usize,
    depth: usize,
    /// Values in flight, one slot per pipeline stage (each slot is a
    /// partial sum that will reach the accumulator `depth` cycles later).
    pipeline: Vec<Option<i64>>,
    /// Root accumulator.
    pub acc: i64,
    /// Adder activations (for energy accounting): each cycle, each active
    /// tree level does its adds.
    pub add_ops: u64,
    cycle: u64,
}

impl AdderTree {
    pub fn new(width: usize) -> AdderTree {
        assert!(width >= 1);
        let depth = (usize::BITS - (width - 1).leading_zeros()) as usize; // ceil(log2)
        AdderTree {
            width,
            depth: depth.max(1),
            pipeline: vec![None; depth.max(1)],
            acc: 0,
            add_ops: 0,
            cycle: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Feed up to `width` values this cycle; returns how many were taken.
    /// Values reduce combinationally into one partial sum that enters the
    /// pipeline; the pipeline drains into the accumulator.
    pub fn feed(&mut self, values: &[i64]) -> usize {
        let take = values.len().min(self.width);
        if take > 0 {
            let partial: i64 = values[..take].iter().sum();
            // adds used: take-1 within the tree this cycle
            self.add_ops += take.saturating_sub(1) as u64;
            // enters stage 0; shifted by tick()
            debug_assert!(self.pipeline[0].is_none(), "feed before tick");
            self.pipeline[0] = Some(partial);
        }
        take
    }

    /// Advance one cycle: shift the pipeline; the last stage folds into the
    /// accumulator (one more add).
    pub fn tick(&mut self) {
        self.cycle += 1;
        let last = self.pipeline.pop().expect("pipeline is never empty");
        if let Some(v) = last {
            self.acc += v;
            self.add_ops += 1;
        }
        self.pipeline.insert(0, None);
    }

    /// Is anything still in flight?
    pub fn busy(&self) -> bool {
        self.pipeline.iter().any(Option::is_some)
    }

    /// Drain fully; returns cycles spent draining.
    pub fn drain(&mut self) -> u64 {
        let mut c = 0;
        while self.busy() {
            self.tick();
            c += 1;
        }
        c
    }

    pub fn cycles(&self) -> u64 {
        self.cycle
    }

    /// Convenience: cycles to reduce `n` values through this tree,
    /// including drain (analytic cross-check for the simulation).
    pub fn reduce_cycles(width: usize, n: usize) -> u64 {
        if n == 0 {
            return 0;
        }
        let feeds = n.div_ceil(width) as u64;
        let depth = AdderTree::new(width).depth() as u64;
        // the last feed's tick overlaps the first drain cycle
        feeds + depth - 1
    }
}

/// Run a full reduction of `values` through a fresh tree of `width`;
/// returns (sum, cycles).
pub fn simulate_reduction(width: usize, values: &[i64]) -> (i64, u64) {
    let mut tree = AdderTree::new(width);
    let mut i = 0;
    let mut cycles = 0u64;
    while i < values.len() {
        let take = tree.feed(&values[i..]);
        i += take;
        tree.tick();
        cycles += 1;
    }
    cycles += tree.drain();
    (tree.acc, cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::propcheck::forall;

    #[test]
    fn membank_ports_limit_reads_per_cycle() {
        let mut b = MemBank::new(1024.0, 2);
        assert!(b.try_read());
        assert!(b.try_read());
        assert!(!b.try_read()); // third read stalls
        assert_eq!(b.stalls, 1);
        b.tick();
        assert!(b.try_read());
        assert_eq!(b.reads, 3);
    }

    #[test]
    fn tree_sums_correctly() {
        forall("adder tree sum == naive sum", 100, |g| {
            let width = g.one_of(&[1usize, 2, 4, 8, 16]);
            let n = g.usize(0, 64);
            let values = g.vec_of(n, |g| g.i64(-1000, 1000));
            let (sum, _) = simulate_reduction(width, &values);
            assert_eq!(sum, values.iter().sum::<i64>());
        });
    }

    #[test]
    fn simulated_cycles_match_analytic() {
        forall("sim cycles == analytic", 100, |g| {
            let width = g.one_of(&[1usize, 2, 4, 8, 16, 32]);
            let n = g.usize(1, 100);
            let values = g.vec_of(n, |_| 1i64);
            let (_, cycles) = simulate_reduction(width, &values);
            assert_eq!(cycles, AdderTree::reduce_cycles(width, n));
        });
    }

    #[test]
    fn wider_tree_is_faster() {
        // Fig 4: "that might be sped up by having a tree of adders".
        let values: Vec<i64> = (0..25).collect(); // a 5x5 RF
        let (_, c1) = simulate_reduction(1, &values);
        let (_, c4) = simulate_reduction(4, &values);
        let (_, c8) = simulate_reduction(8, &values);
        assert!(c1 > c4 && c4 > c8, "c1={c1} c4={c4} c8={c8}");
    }

    #[test]
    fn serial_adder_is_the_bottleneck() {
        // width=1: cycles ≈ n (the paper's bottleneck case).
        let values = vec![1i64; 100];
        let (_, c) = simulate_reduction(1, &values);
        assert!(c >= 100);
    }

    #[test]
    fn add_ops_counted() {
        // Reducing n values needs exactly n-1 adds... plus the accumulator
        // folds (one per feed chunk). Check total ≥ n-1 and the sum exact.
        let values = vec![2i64; 17];
        let mut tree = AdderTree::new(4);
        let mut i = 0;
        while i < values.len() {
            i += tree.feed(&values[i..]);
            tree.tick();
        }
        tree.drain();
        assert_eq!(tree.acc, 34);
        assert!(tree.add_ops >= 16);
    }

    #[test]
    fn depth_is_log2_width() {
        assert_eq!(AdderTree::new(1).depth(), 1);
        assert_eq!(AdderTree::new(2).depth(), 1);
        assert_eq!(AdderTree::new(4).depth(), 2);
        assert_eq!(AdderTree::new(8).depth(), 3);
        assert_eq!(AdderTree::new(16).depth(), 4);
        let _ = Rng::new(0); // keep import used
    }
}
