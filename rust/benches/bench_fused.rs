//! Fused vs unfused stage-walk benchmark on the depth-scaling graphs
//! (the same 2/4/8-conv topologies as `bench_network`): end-to-end
//! imgs/sec for the fused code-domain pipeline (tiled
//! conv→requantize→pool chains, absorbed-requantize tables) against the
//! unfused per-stage reference walk, per depth. Results land in the JSON
//! file named by `PCILT_BENCH_JSON` (`BENCH_fused.json` in CI), which
//! also asserts bit-identity between the two walks before timing.

use std::sync::Arc;

use pcilt::model::{CompiledNetwork, EngineChoice, NetworkSpec, StageSpec};
use pcilt::pcilt::TableStore;
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::timing::{bench, section, BenchOpts, BenchResult};

/// `PCILT_BENCH_QUICK=1` shrinks the measurement budget (CI smoke runs).
fn bench_opts() -> BenchOpts {
    if std::env::var("PCILT_BENCH_QUICK").is_ok() {
        BenchOpts::quick()
    } else {
        BenchOpts::default()
    }
}

const ACT_BITS: u32 = 2;
const IMG: usize = 36;
const BATCH: usize = 8;

/// A `depth`-conv graph: conv(k3)+requant per stage, one 2x2 pool at the
/// end, dense head (same shape as `bench_network::depth_spec`).
fn depth_spec(depth: usize) -> NetworkSpec {
    let mut stages: Vec<StageSpec> = (0..depth)
        .flat_map(|_| {
            [
                StageSpec::Conv {
                    out_ch: 8,
                    kernel: 3,
                    stride: 1,
                    engine: EngineChoice::Auto,
                },
                StageSpec::Requantize { scale: 0.05 },
            ]
        })
        .collect();
    stages.push(StageSpec::MaxPool { k: 2, floor: false });
    stages.push(StageSpec::Dense { classes: 10 });
    NetworkSpec {
        act_bits: ACT_BITS,
        img: IMG,
        in_ch: 1,
        stages,
    }
}

struct Row {
    depth: usize,
    engines: String,
    absorbed: usize,
    fused_imgs_per_sec: f64,
    unfused_imgs_per_sec: f64,
    fused: BenchResult,
    unfused: BenchResult,
}

fn imgs_per_sec(r: &BenchResult) -> f64 {
    BATCH as f64 / (r.ns_per_iter() * 1e-9)
}

fn compile(spec: &NetworkSpec, store: &Arc<TableStore>) -> CompiledNetwork {
    let weights = spec.seeded_weights(spec.conv_count() as u64).expect("spec is valid");
    spec.compile_with_defaults(&weights, store).expect("depth spec compiles")
}

fn main() {
    section("Fused code-domain pipeline vs unfused stage walk: 2/4/8-conv graphs");
    let opts = bench_opts();
    let mut rng = Rng::new(7);
    let codes = Tensor4::random_activations(
        Shape4::new(BATCH, IMG, IMG, 1),
        ACT_BITS,
        &mut rng,
    );
    let mut rows = Vec::new();
    for depth in [2usize, 4, 8] {
        let spec = depth_spec(depth);
        let store = Arc::new(TableStore::new());
        let fused_net = compile(&spec, &store);
        let unfused_net = compile(&spec, &store).with_fused(false);
        assert_eq!(
            fused_net.forward_fused_serial(&codes),
            unfused_net.forward_serial(&codes),
            "fused and unfused walks must be bit-identical before timing"
        );
        let engines = fused_net.conv_engine_names().join("+");
        let absorbed = fused_net.absorbed_requant_count();
        let fused = bench(&format!("{depth}-conv fused (batch {BATCH})"), &opts, || {
            fused_net.forward_fused_serial(&codes)
        });
        println!("{}", fused.report());
        let unfused = bench(&format!("{depth}-conv unfused (batch {BATCH})"), &opts, || {
            unfused_net.forward_serial(&codes)
        });
        println!("{}", unfused.report());
        let (f, u) = (imgs_per_sec(&fused), imgs_per_sec(&unfused));
        println!(
            "depth {depth}: fused {f:.0} imgs/sec vs unfused {u:.0} imgs/sec \
             (x{:.2}), engines [{engines}], {absorbed} absorbed requants",
            f / u
        );
        rows.push(Row {
            depth,
            engines,
            absorbed,
            fused_imgs_per_sec: f,
            unfused_imgs_per_sec: u,
            fused,
            unfused,
        });
    }

    if let Ok(path) = std::env::var("PCILT_BENCH_JSON") {
        write_bench_json(&path, &rows);
        println!("wrote {path}");
    }
}

/// Hand-rolled JSON (no serde offline); names are plain ASCII.
fn write_bench_json(path: &str, rows: &[Row]) {
    let mut out = String::new();
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "    {{\"depth\": {}, \"engines\": \"{}\", \"absorbed_requants\": {}, \
             \"fused_imgs_per_sec\": {:.1}, \"unfused_imgs_per_sec\": {:.1}, \
             \"speedup\": {:.3}, \"fused_p50_ns\": {:.1}, \"unfused_p50_ns\": {:.1}, \
             \"iters\": {}}}",
            r.depth,
            r.engines,
            r.absorbed,
            r.fused_imgs_per_sec,
            r.unfused_imgs_per_sec,
            r.fused_imgs_per_sec / r.unfused_imgs_per_sec,
            r.fused.summary.p50,
            r.unfused.summary.p50,
            r.fused.iters,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"bench_fused/fused_vs_unfused\",\n  \"act_bits\": {ACT_BITS},\n  \
         \"img\": {IMG},\n  \"batch\": {BATCH},\n  \"rows\": [\n{out}\n  ]\n}}\n"
    );
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("failed to write {path}: {e}");
    }
}
