//! E1 / E8 / E9 / E12 — CPU engine benchmarks.
//!
//! * **E1** (Figs 1–2): PCILT vs DM across layer shapes and activation
//!   cardinalities — exactness asserted, wall time reported.
//! * **E8**: custom convolutional functions cost the same at inference as
//!   plain multiplication (the table hides the function).
//! * **E9**: PCILT-as-weights — training-convergence and parameter counts
//!   for the four adjustment ranges.
//! * **E12**: the paper's own CPU caveat — the DM-vs-PCILT crossover as
//!   weight width grows and tables fall out of cache.
//!
//! Filter with `cargo bench --bench bench_engines -- <e1|custom|asweights|crossover>`.

use pcilt::model::{random_params, EngineChoice, QuantCnn};
use pcilt::pcilt::as_weights::{AdjustRange, TableParamLayer};
use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::parallel::{conv_parallel, effective_threads};
use pcilt::pcilt::{ConvFunc, DmEngine, PciltEngine, SharedEngine};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::timing::{bench, section, BenchOpts, BenchResult};

fn filter_match(name: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    args.is_empty() || args.iter().any(|a| name.contains(a.as_str()))
}

/// `PCILT_BENCH_QUICK=1` shrinks the measurement budget (CI smoke runs).
fn bench_opts() -> BenchOpts {
    if std::env::var("PCILT_BENCH_QUICK").is_ok() {
        BenchOpts::quick()
    } else {
        BenchOpts::default()
    }
}

fn e1() {
    if !filter_match("e1") {
        return;
    }
    section("E1: PCILT vs DM across shapes and cardinalities (Figs 1-2)");
    let opts = BenchOpts::default();
    let mut rng = Rng::new(1);
    println!(
        "{:<34} {:>10} {:>10} {:>9}",
        "layer", "dm p50", "pcilt p50", "speedup"
    );
    for (h, w_dim, cin, cout, k, bits) in [
        (32usize, 32usize, 8usize, 16usize, 3usize, 4u32),
        (32, 32, 8, 16, 5, 4),
        (64, 64, 16, 32, 3, 4),
        (64, 64, 16, 32, 3, 8),
        (64, 64, 4, 8, 5, 2),
        (96, 96, 1, 8, 5, 1),
    ] {
        let x = Tensor4::random_activations(Shape4::new(1, h, w_dim, cin), bits, &mut rng);
        let w = Tensor4::random_weights(Shape4::new(cout, k, k, cin), 8, &mut rng);
        let geom = ConvGeometry::unit_stride(k, k);
        let dm = DmEngine::new(w.clone(), geom);
        let pc = PciltEngine::new(&w, bits, geom);
        assert_eq!(dm.conv(&x), pc.conv(&x), "exactness violated");
        let td = bench("dm", &opts, || dm.conv(&x));
        let tp = bench("pcilt", &opts, || pc.conv(&x));
        println!(
            "{:<34} {:>10} {:>10} {:>8.2}x",
            format!("{h}x{w_dim}x{cin}->{cout} k{k} a{bits}"),
            pcilt::util::stats::fmt_ns(td.ns_per_iter()),
            pcilt::util::stats::fmt_ns(tp.ns_per_iter()),
            td.ns_per_iter() / tp.ns_per_iter()
        );
    }
}

fn custom() {
    if !filter_match("custom") {
        return;
    }
    section("E8: custom convolutional functions — identical inference cost");
    let opts = BenchOpts::default();
    let mut rng = Rng::new(2);
    let x = Tensor4::random_activations(Shape4::new(1, 64, 64, 8), 4, &mut rng);
    let w = Tensor4::random_weights(Shape4::new(16, 3, 3, 8), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    for f in [
        ConvFunc::Mul,
        ConvFunc::SatMul { max: 512 },
        ConvFunc::LogMul { base: 2.0 },
        ConvFunc::Codebook {
            codes: (0..16).map(|i| (i as f32).sqrt()).collect(),
        },
    ] {
        let e = PciltEngine::with_func(&w, 4, geom, &f);
        let t = bench(f.name(), &opts, || e.conv(&x));
        println!("{}", t.report());
    }
    println!("(the function only affects table *construction*; fetch+add cost is constant)");
}

fn asweights() {
    if !filter_match("asweights") {
        return;
    }
    section("E9: PCILT-as-weights — four adjustment ranges");
    let mut rng = Rng::new(3);
    let geom = ConvGeometry::unit_stride(3, 3);
    let target = TableParamLayer::random(4, geom, 2, 2, 2.0, &mut rng);
    let x = Tensor4::random_activations(Shape4::new(8, 8, 8, 2), 2, &mut rng);
    let (y_t, _) = target.forward(&x);
    println!(
        "{:<16} {:>8} {:>12} {:>12} {:>10}",
        "range", "params", "loss@0", "loss@80", "reduction"
    );
    for range in AdjustRange::ALL {
        let mut model = TableParamLayer::random(4, geom, 2, 2, 0.1, &mut rng);
        let mut first = 0.0;
        let mut last = 0.0;
        for step in 0..80 {
            let (y, codes) = model.forward(&x);
            let n = y.data().len() as f32;
            let mut loss = 0f32;
            let grad = Tensor4::from_vec(
                y.shape(),
                y.data()
                    .iter()
                    .zip(y_t.data().iter())
                    .map(|(&a, &b)| {
                        loss += (a - b) * (a - b);
                        (a - b) / n
                    })
                    .collect(),
            );
            loss /= 2.0 * n;
            if step == 0 {
                first = loss;
            }
            last = loss;
            model.sgd_step(&grad, &codes, range, 0.5);
        }
        println!(
            "{:<16} {:>8} {:>12.4} {:>12.4} {:>9.1}x",
            range.name(),
            model.param_count(range),
            first,
            last,
            first / last.max(1e-9)
        );
    }
}

fn crossover() {
    if !filter_match("crossover") {
        return;
    }
    section("E12: CPU crossover — PCILT vs DM as tables grow (paper's CPU caveat)");
    let opts = BenchOpts::default();
    let mut rng = Rng::new(4);
    println!(
        "{:<26} {:>12} {:>10} {:>10} {:>9}",
        "config", "table bytes", "dm p50", "pcilt p50", "ratio"
    );
    for (bits, cin, cout) in [
        (1u32, 8usize, 16usize),
        (2, 8, 16),
        (4, 8, 16),
        (8, 8, 16),
        (8, 32, 64),
    ] {
        let x = Tensor4::random_activations(Shape4::new(1, 48, 48, cin), bits, &mut rng);
        let w = Tensor4::random_weights(Shape4::new(cout, 3, 3, cin), 8, &mut rng);
        let geom = ConvGeometry::unit_stride(3, 3);
        let dm = DmEngine::new(w.clone(), geom);
        let pc = PciltEngine::new(&w, bits, geom);
        let td = bench("dm", &opts, || dm.conv(&x));
        let tp = bench("pcilt", &opts, || pc.conv(&x));
        println!(
            "{:<26} {:>12} {:>10} {:>10} {:>8.2}x",
            format!("a{bits} {cin}->{cout}"),
            pcilt::util::stats::fmt_bytes(pc.tables().bytes(32)),
            pcilt::util::stats::fmt_ns(td.ns_per_iter()),
            pcilt::util::stats::fmt_ns(tp.ns_per_iter()),
            td.ns_per_iter() / tp.ns_per_iter()
        );
    }
    // Shared tables reduce footprint at an indirection cost:
    let x = Tensor4::random_activations(Shape4::new(1, 48, 48, 8), 4, &mut rng);
    let w = Tensor4::random_weights(Shape4::new(16, 3, 3, 8), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let sh = SharedEngine::new(&w, 4, geom);
    let t = bench("shared (indirect)", &opts, || sh.conv(&x));
    println!("{}", t.report());
}

fn ablation() {
    if !filter_match("ablation") {
        return;
    }
    section("Ablation: table layout — canonical [oc][p][a] gathers vs channels-last rows");
    let opts = BenchOpts::default();
    let mut rng = Rng::new(5);
    let x = Tensor4::random_activations(Shape4::new(1, 64, 64, 8), 4, &mut rng);
    let w = Tensor4::random_weights(Shape4::new(16, 3, 3, 8), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let engine = PciltEngine::new(&w, 4, geom);
    // canonical-layout inner loop (the pre-optimization design), written
    // against the same tables so only the layout/loop changes:
    let canonical = |x: &Tensor4<u8>| {
        let tables = engine.tables();
        let s = x.shape();
        let out_shape = geom.out_shape(s, tables.out_ch);
        let mut out = Tensor4::<i32>::zeros(out_shape);
        let card = tables.card;
        let mut offs = vec![0usize; tables.positions];
        for n in 0..s.n {
            for oy in 0..out_shape.h {
                for ox in 0..out_shape.w {
                    let mut p = 0;
                    for ky in 0..geom.kh {
                        let row = x.row_span(n, oy + ky, ox, geom.kw);
                        for &a in row {
                            offs[p] = p * card + a as usize;
                            p += 1;
                        }
                    }
                    for oc in 0..tables.out_ch {
                        let ch = tables.channel_tables(oc);
                        let mut acc = 0i32;
                        for &o in offs.iter() {
                            acc += ch[o];
                        }
                        out.set(n, oy, ox, oc, acc);
                    }
                }
            }
        }
        out
    };
    assert_eq!(canonical(&x), engine.conv(&x));
    let tc = bench("canonical gathers", &opts, || canonical(&x));
    let tl = bench("channels-last rows", &opts, || engine.conv(&x));
    println!("{}", tc.report());
    println!("{}", tl.report());
    println!(
        "layout speedup: {:.2}x (the §Perf L3 hot-path-1 change)",
        tc.ns_per_iter() / tl.ns_per_iter()
    );
}

/// Parallel batch execution: serial vs scoped-thread data parallelism over
/// the batch dimension, at raw-engine and full-model level. Exactness is
/// asserted; results (and speedups) optionally land in the JSON file named
/// by `PCILT_BENCH_JSON` so CI can track the perf trajectory.
fn parallel_batch() {
    if !filter_match("parallel") {
        return;
    }
    let threads = effective_threads(0, usize::MAX);
    section(&format!(
        "Parallel batch execution: 1 vs {threads} threads over the N dimension"
    ));
    let opts = bench_opts();
    let mut rng = Rng::new(6);

    // Raw engine level: one conv layer over a batch of 16.
    let x = Tensor4::random_activations(Shape4::new(16, 48, 48, 4), 4, &mut rng);
    let w = Tensor4::random_weights(Shape4::new(16, 3, 3, 4), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let engine = PciltEngine::new(&w, 4, geom);
    assert_eq!(
        conv_parallel(&engine, &x, threads),
        engine.conv(&x),
        "parallel conv must be bit-identical"
    );
    let conv_serial = bench("pcilt conv b16 serial", &opts, || engine.conv(&x));
    let conv_par = bench(&format!("pcilt conv b16 {threads}t"), &opts, || {
        conv_parallel(&engine, &x, threads)
    });
    println!("{}", conv_serial.report());
    println!("{}", conv_par.report());
    let conv_speedup = conv_serial.ns_per_iter() / conv_par.ns_per_iter();
    println!("conv speedup: {conv_speedup:.2}x on {threads} threads");

    // Full-model level: QuantCnn forward over a batch of 16.
    let params = random_params(4, &mut rng);
    let serial_model = QuantCnn::new(params.clone(), EngineChoice::Pcilt).with_threads(1);
    let par_model = QuantCnn::new(params, EngineChoice::Pcilt).with_threads(threads);
    let codes = Tensor4::random_activations(Shape4::new(16, 16, 16, 1), 4, &mut rng);
    assert_eq!(
        par_model.forward(&codes),
        serial_model.forward(&codes),
        "parallel forward must be bit-identical"
    );
    let model_serial = bench("model forward b16 serial", &opts, || {
        serial_model.forward(&codes)
    });
    let model_par = bench(&format!("model forward b16 {threads}t"), &opts, || {
        par_model.forward(&codes)
    });
    println!("{}", model_serial.report());
    println!("{}", model_par.report());
    let model_speedup = model_serial.ns_per_iter() / model_par.ns_per_iter();
    println!("model speedup: {model_speedup:.2}x on {threads} threads");

    if let Ok(path) = std::env::var("PCILT_BENCH_JSON") {
        let results = [&conv_serial, &conv_par, &model_serial, &model_par];
        write_bench_json(&path, threads, &results, conv_speedup, model_speedup);
        println!("wrote {path}");
    }
}

/// Hand-rolled JSON (no serde offline); names are plain ASCII. The
/// `*imgs_per_sec` throughput figures (batch 16 over the parallel-path
/// p50) are what `pcilt bench-check` gates CI regressions on.
fn write_bench_json(
    path: &str,
    threads: usize,
    results: &[&BenchResult],
    conv_speedup: f64,
    model_speedup: f64,
) {
    let batch = 16.0;
    let conv_imgs_per_sec = batch / (results[1].ns_per_iter() * 1e-9);
    let model_imgs_per_sec = batch / (results[3].ns_per_iter() * 1e-9);
    let mut rows = String::new();
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"p50_ns\": {:.1}, \"mean_ns\": {:.1}, \"iters\": {}}}",
            r.name, r.summary.p50, r.summary.mean, r.iters
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"bench_engines/parallel\",\n  \"batch\": 16,\n  \
         \"threads\": {threads},\n  \"conv_speedup\": {conv_speedup:.3},\n  \
         \"model_speedup\": {model_speedup:.3},\n  \
         \"conv_imgs_per_sec\": {conv_imgs_per_sec:.1},\n  \
         \"model_imgs_per_sec\": {model_imgs_per_sec:.1},\n  \"results\": [\n{rows}\n  ]\n}}\n"
    );
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("could not write {path}: {e}");
    }
}

fn main() {
    e1();
    ablation();
    custom();
    asweights();
    crossover();
    parallel_batch();
}
