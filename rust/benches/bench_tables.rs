//! Table-store lifecycle benchmark: cold build vs warm (persisted) load
//! vs dedup-shared construction.
//!
//! The paper's tables are *pre-calculated*; this bench measures what the
//! `TableStore` buys a serving deployment around that fact:
//!
//! * **cold** — fresh store, full table build (the per-boot cost the store
//!   eliminates);
//! * **warm** — a fresh store loading the checksummed `tables.bin` cache a
//!   previous boot persisted (`pcilt tables prebuild` / `[tables] persist`);
//! * **dedup** — N identical layers borrowing one allocation vs N private
//!   builds (the §Using Shared PCILTs footprint, attacked across layers).
//!
//! * **tiered capacity** — palette-packed vs flat residency under one
//!   fixed byte budget: how many models' tables a warm boot keeps
//!   resident (the `*models_per_budget` figures CI gates), with a
//!   bit-identity check and a p99 gather-latency comparison.
//!
//! Results (and speedups) land in the JSON file named by
//! `PCILT_BENCH_JSON` so CI tracks the trajectory (`BENCH_tables.json`).

use pcilt::pcilt::engine::ConvGeometry;
use pcilt::pcilt::{ConvFunc, PciltEngine, TableStore};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::stats::fmt_bytes;
use pcilt::util::timing::{bench, section, BenchOpts, BenchResult};

/// `PCILT_BENCH_QUICK=1` shrinks the measurement budget (CI smoke runs).
fn bench_opts() -> BenchOpts {
    if std::env::var("PCILT_BENCH_QUICK").is_ok() {
        BenchOpts::quick()
    } else {
        BenchOpts::default()
    }
}

const DEDUP_LAYERS: usize = 8;

fn main() {
    section("Table lifecycle: cold build vs warm (persisted) load vs dedup-shared");
    let opts = bench_opts();
    let mut rng = Rng::new(11);
    // A serving-sized layer: 32 oc x (3*3*16) positions x 2^8 cardinality
    // = ~1.2M table entries (~4.7 MB), the scale §Using Shared PCILTs
    // worries about per layer.
    let w = Tensor4::random_weights(Shape4::new(32, 3, 3, 16), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let bits = 8u32;
    let f = ConvFunc::Mul;

    // Cold: every boot pays the full build.
    let cold = bench("cold build", &opts, || {
        let store = TableStore::new();
        PciltEngine::from_store(&store, &w, bits, geom, &f).tables().entries()
    });
    println!("{}", cold.report());

    // Persist once, then measure warm boots loading the cache.
    let dir = std::env::temp_dir().join("pcilt_bench_tables_cache");
    let _ = std::fs::remove_dir_all(&dir);
    {
        let store = TableStore::new();
        let _e = PciltEngine::from_store(&store, &w, bits, geom, &f);
        store.save(&dir).expect("persist table cache");
    }
    let warm = bench("warm load (persisted)", &opts, || {
        let store = TableStore::new();
        store.load(&dir).expect("load table cache");
        let e = PciltEngine::from_store(&store, &w, bits, geom, &f);
        assert_eq!(store.stats().builds, 0, "warm boot must not build");
        e.tables().entries()
    });
    println!("{}", warm.report());

    // Dedup: N identical layers — owned builds N times, the store once.
    let owned = bench(&format!("{DEDUP_LAYERS} layers, owned tables"), &opts, || {
        (0..DEDUP_LAYERS)
            .map(|_| PciltEngine::new(&w, bits, geom).tables().entries())
            .sum::<usize>()
    });
    println!("{}", owned.report());
    let shared = bench(&format!("{DEDUP_LAYERS} layers, dedup-shared"), &opts, || {
        let store = TableStore::new();
        (0..DEDUP_LAYERS)
            .map(|_| PciltEngine::from_store(&store, &w, bits, geom, &f).tables().entries())
            .sum::<usize>()
    });
    println!("{}", shared.report());

    let warm_speedup = cold.ns_per_iter() / warm.ns_per_iter();
    let dedup_speedup = owned.ns_per_iter() / shared.ns_per_iter();
    println!("warm load speedup over cold build: {warm_speedup:.2}x");
    println!("dedup-shared speedup over {DEDUP_LAYERS} owned builds: {dedup_speedup:.2}x");

    let tier = tiered_capacity(&opts, &mut rng);

    if let Ok(path) = std::env::var("PCILT_BENCH_JSON") {
        let results = [&cold, &warm, &owned, &shared];
        write_bench_json(&path, &results, warm_speedup, dedup_speedup, &tier);
        println!("wrote {path}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Figures from the tiered-capacity section.
struct TierFigures {
    flat_models: u64,
    packed_models: u64,
    ratio: f64,
    flat_p99_ns: f64,
    packed_p99_ns: f64,
}

/// How many models' tables one fixed byte budget keeps resident, flat vs
/// palette-packed — measured the way serving hits it: a budgeted warm
/// boot loading the persisted cache (loads stay packed-only until first
/// gather). Packing is exact, so the section first gates on bit-identity,
/// then compares p99 gather latency against the flat reference.
fn tiered_capacity(opts: &BenchOpts, rng: &mut Rng) -> TierFigures {
    section("Tiered capacity: packed vs flat models resident in one budget");
    const MODELS: usize = 12;
    const TIER_BUDGET: u64 = 1024 * 1024; // 1 MiB of resident tables
    let bits = 8u32;
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    // Ternary weights: the low-cardinality regime palette packing targets
    // (quantized {-1,0,1} backbones); each model is a distinct tensor, so
    // nothing here dedups — capacity comes from compression alone.
    let models: Vec<Tensor4<i8>> = (0..MODELS)
        .map(|i| {
            let mut r = Rng::new(1000 + i as u64);
            Tensor4::from_fn(Shape4::new(8, 3, 3, 4), |_, _, _, _| *r.choose(&[-1i8, 0, 1]))
        })
        .collect();

    // Bit-identity gate before any timing: every packed gather must equal
    // the flat in-RAM reference exactly.
    let x = Tensor4::random_activations(Shape4::new(1, 8, 8, 4), bits, rng);
    let flat_store = TableStore::with_budget(0);
    flat_store.set_pack(false);
    let packed_store = TableStore::with_budget(0);
    packed_store.set_pack(true);
    for w in &models {
        let ef = PciltEngine::from_store(&flat_store, w, bits, geom, &f);
        let ep = PciltEngine::from_store(&packed_store, w, bits, geom, &f);
        assert_eq!(ef.conv(&x), ep.conv(&x), "packed gather must be bit-identical");
    }
    let ps = packed_store.stats();
    assert_eq!(
        ps.packed_entries as usize, MODELS,
        "ternary tables must all take the packed representation"
    );
    println!(
        "pack ratio: {:.2}x ({} logical -> {} packed across {MODELS} models)",
        ps.packed_logical_bytes / ps.packed_bytes,
        fmt_bytes(ps.packed_logical_bytes),
        fmt_bytes(ps.packed_bytes),
    );

    // Capacity under the budget: persist once, then count what a budgeted
    // warm boot keeps resident.
    let dir = std::env::temp_dir().join("pcilt_bench_tables_tiered");
    let _ = std::fs::remove_dir_all(&dir);
    flat_store.save(&dir).expect("persist tiered cache");
    let resident_models = |pack: bool| -> u64 {
        let store = TableStore::with_budget(TIER_BUDGET);
        store.set_pack(pack);
        store.load(&dir).expect("warm boot against the tiered cache");
        store.stats().entries
    };
    let flat_models = resident_models(false);
    let packed_models = resident_models(true);
    let ratio = packed_models as f64 / flat_models.max(1) as f64;
    println!(
        "budget {}: flat {flat_models} models resident, packed {packed_models} ({ratio:.2}x)",
        fmt_bytes(TIER_BUDGET as f64),
    );
    assert!(
        ratio >= 3.0,
        "packing must fit at least 3x more models in the budget (got {ratio:.2}x)"
    );

    // p99 gather latency: a budgeted packed boot vs the flat reference.
    // The first borrow decodes once; steady-state gathers walk the same
    // decoded table, so the tails should sit within a few percent.
    let warm_packed = TableStore::with_budget(TIER_BUDGET);
    warm_packed.set_pack(true);
    warm_packed.load(&dir).expect("warm boot against the tiered cache");
    let ep = PciltEngine::from_store(&warm_packed, &models[0], bits, geom, &f);
    let ef = PciltEngine::from_store(&flat_store, &models[0], bits, geom, &f);
    let gf = bench("gather, flat resident", opts, || ef.conv(&x));
    println!("{}", gf.report());
    let gp = bench("gather, packed (decode-on-gather)", opts, || ep.conv(&x));
    println!("{}", gp.report());
    println!(
        "p99 gather latency packed/flat: {:.3} (flat {}, packed {})",
        gp.summary.p99 / gf.summary.p99,
        pcilt::util::stats::fmt_ns(gf.summary.p99),
        pcilt::util::stats::fmt_ns(gp.summary.p99),
    );

    std::fs::remove_dir_all(&dir).ok();
    TierFigures {
        flat_models,
        packed_models,
        ratio,
        flat_p99_ns: gf.summary.p99,
        packed_p99_ns: gp.summary.p99,
    }
}

/// Hand-rolled JSON (no serde offline); names are plain ASCII. The
/// `*models_per_budget` keys are the CI-gated capacity figures — keep
/// their document order stable (the gate pairs positionally).
fn write_bench_json(
    path: &str,
    results: &[&BenchResult],
    warm_speedup: f64,
    dedup_speedup: f64,
    tier: &TierFigures,
) {
    let mut rows = String::new();
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"p50_ns\": {:.1}, \"mean_ns\": {:.1}, \"iters\": {}}}",
            r.name, r.summary.p50, r.summary.mean, r.iters
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"bench_tables/lifecycle\",\n  \"dedup_layers\": {DEDUP_LAYERS},\n  \
         \"warm_load_speedup\": {warm_speedup:.3},\n  \"dedup_speedup\": {dedup_speedup:.3},\n  \
         \"flat_models_per_budget\": {},\n  \"packed_models_per_budget\": {},\n  \
         \"capacity_ratio\": {:.3},\n  \"gather_p99_flat_ns\": {:.1},\n  \
         \"gather_p99_packed_ns\": {:.1},\n  \"results\": [\n{rows}\n  ]\n}}\n",
        tier.flat_models,
        tier.packed_models,
        tier.ratio,
        tier.flat_p99_ns,
        tier.packed_p99_ns,
    );
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("failed to write {path}: {e}");
    }
}
