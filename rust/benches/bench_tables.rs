//! Table-store lifecycle benchmark: cold build vs warm (persisted) load
//! vs dedup-shared construction.
//!
//! The paper's tables are *pre-calculated*; this bench measures what the
//! `TableStore` buys a serving deployment around that fact:
//!
//! * **cold** — fresh store, full table build (the per-boot cost the store
//!   eliminates);
//! * **warm** — a fresh store loading the checksummed `tables.bin` cache a
//!   previous boot persisted (`pcilt tables prebuild` / `[tables] persist`);
//! * **dedup** — N identical layers borrowing one allocation vs N private
//!   builds (the §Using Shared PCILTs footprint, attacked across layers).
//!
//! Results (and speedups) land in the JSON file named by
//! `PCILT_BENCH_JSON` so CI tracks the trajectory (`BENCH_tables.json`).

use pcilt::pcilt::engine::ConvGeometry;
use pcilt::pcilt::{ConvFunc, PciltEngine, TableStore};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::timing::{bench, section, BenchOpts, BenchResult};

/// `PCILT_BENCH_QUICK=1` shrinks the measurement budget (CI smoke runs).
fn bench_opts() -> BenchOpts {
    if std::env::var("PCILT_BENCH_QUICK").is_ok() {
        BenchOpts::quick()
    } else {
        BenchOpts::default()
    }
}

const DEDUP_LAYERS: usize = 8;

fn main() {
    section("Table lifecycle: cold build vs warm (persisted) load vs dedup-shared");
    let opts = bench_opts();
    let mut rng = Rng::new(11);
    // A serving-sized layer: 32 oc x (3*3*16) positions x 2^8 cardinality
    // = ~1.2M table entries (~4.7 MB), the scale §Using Shared PCILTs
    // worries about per layer.
    let w = Tensor4::random_weights(Shape4::new(32, 3, 3, 16), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let bits = 8u32;
    let f = ConvFunc::Mul;

    // Cold: every boot pays the full build.
    let cold = bench("cold build", &opts, || {
        let store = TableStore::new();
        PciltEngine::from_store(&store, &w, bits, geom, &f).tables().entries()
    });
    println!("{}", cold.report());

    // Persist once, then measure warm boots loading the cache.
    let dir = std::env::temp_dir().join("pcilt_bench_tables_cache");
    let _ = std::fs::remove_dir_all(&dir);
    {
        let store = TableStore::new();
        let _e = PciltEngine::from_store(&store, &w, bits, geom, &f);
        store.save(&dir).expect("persist table cache");
    }
    let warm = bench("warm load (persisted)", &opts, || {
        let store = TableStore::new();
        store.load(&dir).expect("load table cache");
        let e = PciltEngine::from_store(&store, &w, bits, geom, &f);
        assert_eq!(store.stats().builds, 0, "warm boot must not build");
        e.tables().entries()
    });
    println!("{}", warm.report());

    // Dedup: N identical layers — owned builds N times, the store once.
    let owned = bench(&format!("{DEDUP_LAYERS} layers, owned tables"), &opts, || {
        (0..DEDUP_LAYERS)
            .map(|_| PciltEngine::new(&w, bits, geom).tables().entries())
            .sum::<usize>()
    });
    println!("{}", owned.report());
    let shared = bench(&format!("{DEDUP_LAYERS} layers, dedup-shared"), &opts, || {
        let store = TableStore::new();
        (0..DEDUP_LAYERS)
            .map(|_| PciltEngine::from_store(&store, &w, bits, geom, &f).tables().entries())
            .sum::<usize>()
    });
    println!("{}", shared.report());

    let warm_speedup = cold.ns_per_iter() / warm.ns_per_iter();
    let dedup_speedup = owned.ns_per_iter() / shared.ns_per_iter();
    println!("warm load speedup over cold build: {warm_speedup:.2}x");
    println!("dedup-shared speedup over {DEDUP_LAYERS} owned builds: {dedup_speedup:.2}x");

    if let Ok(path) = std::env::var("PCILT_BENCH_JSON") {
        let results = [&cold, &warm, &owned, &shared];
        write_bench_json(&path, &results, warm_speedup, dedup_speedup);
        println!("wrote {path}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Hand-rolled JSON (no serde offline); names are plain ASCII.
fn write_bench_json(
    path: &str,
    results: &[&BenchResult],
    warm_speedup: f64,
    dedup_speedup: f64,
) {
    let mut rows = String::new();
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            rows.push_str(",\n");
        }
        rows.push_str(&format!(
            "    {{\"name\": \"{}\", \"p50_ns\": {:.1}, \"mean_ns\": {:.1}, \"iters\": {}}}",
            r.name, r.summary.p50, r.summary.mean, r.iters
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"bench_tables/lifecycle\",\n  \"dedup_layers\": {DEDUP_LAYERS},\n  \
         \"warm_load_speedup\": {warm_speedup:.3},\n  \"dedup_speedup\": {dedup_speedup:.3},\n  \
         \"results\": [\n{rows}\n  ]\n}}\n"
    );
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("failed to write {path}: {e}");
    }
}
