//! E7 — shared PCILTs (§Using Shared PCILTs): the ~25 MB / ~18 MB
//! network-size-independent memory claims, the dedup sweep over actual
//! weight cardinality, value-level indirection, and the indirection
//! latency cost on CPU.

use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::memory::shared_pcilt_bytes;
use pcilt::pcilt::shared::{SharedTables, ValueIndirection};
use pcilt::pcilt::{ConvFunc, PciltEngine, SharedEngine};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::stats::{fmt_bytes, fmt_ns};
use pcilt::util::timing::{bench, section, BenchOpts};

fn palette_weights(shape: Shape4, palette: &[i8], rng: &mut Rng) -> Tensor4<i8> {
    Tensor4::from_fn(shape, |_, _, _, _| *rng.choose(palette))
}

fn main() {
    section("E7a: the paper's shared-table arithmetic (network-size independent)");
    let unshared = shared_pcilt_bytes(32, &[10, 16], 32, false);
    let prefix = shared_pcilt_bytes(32, &[10, 16], 32, true);
    println!(
        "32-value INT16 weights x {{INT10, INT16}} acts: {} (paper ~25 MB)",
        fmt_bytes(unshared)
    );
    println!(
        "with prefix sharing:                           {} (paper ~18 MB)",
        fmt_bytes(prefix)
    );
    println!(
        "(same formula, paper's constants are ~3x larger — see EXPERIMENTS.md §E7;\n\
         the headline property holds: the total is independent of network size)"
    );

    section("E7b: dedup sweep — memory savings vs actual weight cardinality");
    let mut rng = Rng::new(21);
    println!(
        "{:<24} {:>10} {:>14} {:>14} {:>9}",
        "palette", "uniques", "dense", "shared", "savings"
    );
    let shape = Shape4::new(32, 5, 5, 16);
    for palette in [
        vec![-1i8, 0, 1],
        vec![-3, -1, 0, 1, 3],
        (-7..=7).collect::<Vec<i8>>(),
        (-63..=63).collect::<Vec<i8>>(),
    ] {
        let w = palette_weights(shape, &palette, &mut rng);
        let t = SharedTables::build(&w, 8, &ConvFunc::Mul);
        let m = t.bytes(16);
        println!(
            "{:<24} {:>10} {:>14} {:>14} {:>8.1}x",
            format!("{} values", palette.len()),
            t.n_unique,
            fmt_bytes(m.dense_bytes),
            fmt_bytes(m.total()),
            m.savings_ratio()
        );
    }

    section("E7c: value-level indirection variant");
    let w = palette_weights(Shape4::new(16, 3, 3, 8), &[-2, -1, 0, 1, 2], &mut rng);
    let vi = ValueIndirection::build(&w, 4, &ConvFunc::Mul);
    let st = SharedTables::build(&w, 4, &ConvFunc::Mul);
    println!(
        "pool of {} unique values; value-indirect {} vs table-pointer {}",
        vi.pool.len(),
        fmt_bytes(vi.bytes(16)),
        fmt_bytes(st.bytes(16).total()),
    );

    section("E7d: the indirection delay on CPU (shared vs dense tables)");
    let opts = BenchOpts::default();
    let x = Tensor4::random_activations(Shape4::new(1, 64, 64, 8), 4, &mut rng);
    let w = palette_weights(Shape4::new(16, 3, 3, 8), &[-3, -1, 0, 1, 3], &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let dense = PciltEngine::new(&w, 4, geom);
    let shared = SharedEngine::new(&w, 4, geom);
    assert_eq!(dense.conv(&x), shared.conv(&x));
    let td = bench("pcilt dense", &opts, || dense.conv(&x));
    let ts = bench("pcilt shared", &opts, || shared.conv(&x));
    println!("{}", td.report());
    println!("{}", ts.report());
    println!(
        "indirection cost: {:.2}x slower, {:.1}x less table memory",
        ts.ns_per_iter() / td.ns_per_iter(),
        dense.tables().bytes(16) / shared.tables().bytes(16).total()
    );
    let _ = fmt_ns(0.0);
}
