//! E6 — the §Basic in-text memory/overhead numbers, regenerated.
//!
//! Every quantitative claim in the paper's §Basic Version:
//! 1.65 GB / ~100 MB / ~75 MB PCILT memory for the 5-layer example net,
//! 6,400 build multiplications, 194,820,000,000 DM multiplications.

use pcilt::pcilt::memory::{
    basic_pcilt_bytes, build_mults_per_filter, dm_mults, paper_memory_report, NetworkSpec,
};
use pcilt::util::stats::{fmt_bytes, fmt_count};

fn main() {
    println!("## E6: memory model vs the paper's §Basic claims\n");
    println!(
        "{:<52} {:>12} {:>12} {:>7}",
        "configuration", "ours", "paper", "ratio"
    );
    for row in paper_memory_report() {
        let paper = row.paper_bytes.unwrap();
        println!(
            "{:<52} {:>12} {:>12} {:>6.2}x",
            row.label,
            fmt_bytes(row.ours_bytes),
            fmt_bytes(paper),
            row.ours_bytes / paper
        );
    }

    // The two ratios the §Basic argument rests on, which must be exact:
    let net8 = NetworkSpec::paper_example();
    let net4 = net8.with_activation_bits(4);
    let r16 = basic_pcilt_bytes(&net8, 16) / basic_pcilt_bytes(&net4, 16);
    let r075 = basic_pcilt_bytes(&net4, net4.product_bits()) / basic_pcilt_bytes(&net4, 16);
    println!("\nINT8->INT4 ratio: {r16:.0}x (paper: 16x, exact)");
    println!("narrow-product ratio: {r075:.2} (paper: 0.75, exact)");

    // Build-cost vs inference-cost (exact integer match with the paper):
    let build = build_mults_per_filter(5, 1, 8);
    let dm = dm_mults(10_000, 768, 1024, 5);
    println!(
        "\nbuild mults (5x5, INT8 acts): {} (paper: 6,400 — {})",
        fmt_count(build as u128),
        if build == 6_400 { "exact" } else { "MISMATCH" }
    );
    println!(
        "DM mults (10k 1024x768 frames): {} (paper: 194,820,000,000 — {})",
        fmt_count(dm as u128),
        if dm == 194_820_000_000 { "exact" } else { "MISMATCH" }
    );
    println!(
        "amortization: the tables pay for themselves after {:.6}% of the workload",
        build as f64 / dm as f64 * 100.0
    );
}
