//! Depth-scaling benchmark for the NetworkSpec → CompiledNetwork API:
//! 2-, 4- and 8-conv layer graphs with planner-chosen (auto) engines per
//! stage, measuring end-to-end imgs/sec, the per-stage engine mix the
//! planner settled on, and the lookup-table bytes each depth holds.
//!
//! This is the scenario the seed repo could not express: the PCILT/DM
//! crossover moves with depth (shrinking maps, growing channel counts),
//! so a real network wants a *different* engine at every stage. Results
//! land in the JSON file named by `PCILT_BENCH_JSON` so CI tracks the
//! trajectory (`BENCH_network.json`).

use std::sync::Arc;

use pcilt::model::{EngineChoice, NetworkSpec, StageSpec};
use pcilt::pcilt::TableStore;
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::timing::{bench, section, BenchOpts, BenchResult};

/// `PCILT_BENCH_QUICK=1` shrinks the measurement budget (CI smoke runs).
fn bench_opts() -> BenchOpts {
    if std::env::var("PCILT_BENCH_QUICK").is_ok() {
        BenchOpts::quick()
    } else {
        BenchOpts::default()
    }
}

const ACT_BITS: u32 = 2;
const IMG: usize = 36;
const BATCH: usize = 8;

/// A `depth`-conv graph: conv(k3)+requant per stage, one 2x2 pool at the
/// end, dense head. IMG=36 leaves room for 8 convs (36 - 2*8 = 20).
fn depth_spec(depth: usize) -> NetworkSpec {
    let mut stages: Vec<StageSpec> = (0..depth)
        .flat_map(|_| {
            [
                StageSpec::Conv {
                    out_ch: 8,
                    kernel: 3,
                    stride: 1,
                    engine: EngineChoice::Auto,
                },
                StageSpec::Requantize { scale: 0.05 },
            ]
        })
        .collect();
    stages.push(StageSpec::MaxPool { k: 2, floor: false });
    stages.push(StageSpec::Dense { classes: 10 });
    NetworkSpec {
        act_bits: ACT_BITS,
        img: IMG,
        in_ch: 1,
        stages,
    }
}

struct Row {
    depth: usize,
    engines: String,
    table_bytes: f64,
    imgs_per_sec: f64,
    result: BenchResult,
}

fn main() {
    section("NetworkSpec depth scaling: 2/4/8-conv graphs, auto engines per stage");
    let opts = bench_opts();
    let mut rng = Rng::new(7);
    let codes = Tensor4::random_activations(
        Shape4::new(BATCH, IMG, IMG, 1),
        ACT_BITS,
        &mut rng,
    );
    let mut rows = Vec::new();
    for depth in [2usize, 4, 8] {
        let spec = depth_spec(depth);
        let weights = spec.seeded_weights(depth as u64).expect("spec is valid");
        let store = Arc::new(TableStore::new());
        let net = spec
            .compile_with_defaults(&weights, &store)
            .expect("depth spec compiles");
        let engines = net.conv_engine_names().join("+");
        let table_bytes = store.stats().bytes;
        let result = bench(&format!("{depth}-conv forward (batch {BATCH})"), &opts, || {
            net.forward(&codes)
        });
        println!("{}", result.report());
        let imgs_per_sec = BATCH as f64 / (result.ns_per_iter() * 1e-9);
        println!(
            "depth {depth}: {imgs_per_sec:.0} imgs/sec, engines [{engines}], \
             tables {table_bytes:.0} B"
        );
        rows.push(Row {
            depth,
            engines,
            table_bytes,
            imgs_per_sec,
            result,
        });
    }

    if rows.len() >= 2 {
        let first = &rows[0];
        let last = &rows[rows.len() - 1];
        println!(
            "depth {} -> {}: throughput x{:.2}, table bytes x{:.2}",
            first.depth,
            last.depth,
            last.imgs_per_sec / first.imgs_per_sec,
            if first.table_bytes > 0.0 {
                last.table_bytes / first.table_bytes
            } else {
                f64::NAN
            },
        );
    }

    if let Ok(path) = std::env::var("PCILT_BENCH_JSON") {
        write_bench_json(&path, &rows);
        println!("wrote {path}");
    }
}

/// Hand-rolled JSON (no serde offline); names are plain ASCII.
fn write_bench_json(path: &str, rows: &[Row]) {
    let mut out = String::new();
    for (i, r) in rows.iter().enumerate() {
        if i > 0 {
            out.push_str(",\n");
        }
        out.push_str(&format!(
            "    {{\"depth\": {}, \"engines\": \"{}\", \"table_bytes\": {:.0}, \
             \"imgs_per_sec\": {:.1}, \"p50_ns\": {:.1}, \"iters\": {}}}",
            r.depth,
            r.engines,
            r.table_bytes,
            r.imgs_per_sec,
            r.result.summary.p50,
            r.result.iters,
        ));
    }
    let json = format!(
        "{{\n  \"bench\": \"bench_network/depth_scaling\",\n  \"act_bits\": {ACT_BITS},\n  \
         \"img\": {IMG},\n  \"batch\": {BATCH},\n  \"rows\": [\n{out}\n  ]\n}}\n"
    );
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("failed to write {path}: {e}");
    }
}
