//! E2 / E3 — ASIC simulator benchmarks (the paper's Figs 3–4 claims).
//!
//! Regenerates the engine-comparison table at every activation
//! cardinality, the adder-tree sweep, the SRAM/ROM trade, and the lane
//! scaling curve. Filter with
//! `cargo bench --bench bench_asic -- <engines|tree|lanes>`.

use pcilt::asic::units::{simulate_reduction, AdderTree};
use pcilt::asic::{
    report::comparison_table, simulate_dm, simulate_fft, simulate_pcilt, simulate_segment,
    simulate_winograd, LayerWorkload, TableMem,
};
use pcilt::util::stats::fmt_count;

fn filter_match(name: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    args.is_empty() || args.iter().any(|a| name.contains(a.as_str()))
}

fn engines() {
    if !filter_match("engines") {
        return;
    }
    let lanes = 16;
    for act_bits in [1u32, 2, 4, 8] {
        let wl = LayerWorkload {
            act_bits,
            k: 3,
            ..LayerWorkload::default_small()
        };
        let mut reports = vec![
            simulate_dm(&wl, lanes),
            simulate_pcilt(&wl, lanes, 8, TableMem::Sram),
            simulate_pcilt(&wl, lanes, 8, TableMem::Rom),
        ];
        if act_bits <= 2 {
            reports.push(simulate_segment(
                &wl,
                lanes,
                (8 / act_bits) as usize,
                TableMem::Sram,
            ));
        }
        reports.push(simulate_winograd(&wl, lanes));
        reports.push(simulate_fft(&wl, lanes));
        comparison_table(
            &format!("E2: ASIC engines at INT{act_bits} activations (Fig 3)"),
            &wl,
            &reports,
            1.0,
        )
        .print();
    }
}

fn tree() {
    if !filter_match("tree") {
        return;
    }
    println!("\n## E3: adder tree (Fig 4) — cycle-stepped simulation");
    // Reduce one 5x5x8 = 200-position RF at each width; cycle counts come
    // from the *simulated* pipeline, cross-checked against the analytic
    // formula inside the simulator's tests.
    let values: Vec<i64> = (0..200).map(|i| (i % 17) as i64).collect();
    println!(
        "{:<8} {:>10} {:>8} {:>10} {:>10}",
        "width", "cycles", "depth", "speedup", "add ops"
    );
    let (_, base) = simulate_reduction(1, &values);
    for width in [1usize, 2, 4, 8, 16, 32, 64] {
        let mut t = AdderTree::new(width);
        let mut i = 0;
        let mut cycles = 0u64;
        while i < values.len() {
            i += t.feed(&values[i..]);
            t.tick();
            cycles += 1;
        }
        cycles += t.drain();
        println!(
            "{:<8} {:>10} {:>8} {:>9.2}x {:>10}",
            width,
            cycles,
            t.depth(),
            base as f64 / cycles as f64,
            t.add_ops
        );
    }
    println!("(width=1 is the serial-adder bottleneck the paper calls out)");
}

fn lanes() {
    if !filter_match("lanes") {
        return;
    }
    println!("\n## E2c: lane scaling (how many PCILT units fit vs DM MACs)");
    let wl = LayerWorkload {
        act_bits: 2,
        k: 3,
        ..LayerWorkload::default_small()
    };
    println!(
        "{:<8} {:>14} {:>14} {:>12} {:>12}",
        "lanes", "pcilt cycles", "dm cycles", "pcilt area", "dm area"
    );
    for lanes in [1usize, 4, 16, 64, 256] {
        let p = simulate_pcilt(&wl, lanes, 4, TableMem::Rom);
        let d = simulate_dm(&wl, lanes);
        println!(
            "{:<8} {:>14} {:>14} {:>12} {:>12}",
            lanes,
            fmt_count(p.cycles as u128),
            fmt_count(d.cycles as u128),
            format!("{:.0}um2", p.area_um2),
            format!("{:.0}um2", d.area_um2),
        );
    }
}

fn main() {
    engines();
    tree();
    lanes();
}
