//! E4 / E5 — segment-offset benchmarks (Figs 5–7).
//!
//! * **E4**: the BoolHash speedup curve — boolean activations, segment
//!   width N ∈ {1,2,4,8,16}, vs scalar DM (paper: 6.59× at N=8).
//! * **E5**: Fig 7 layout plans — zero-skipping and position reuse.
//!
//! Filter with `cargo bench --bench bench_segments -- <boolhash|layout>`.

use pcilt::pcilt::dm::conv_reference;
use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::{
    DmEngine, LayoutEngine, LayoutPlan, PciltEngine, RowSegmentEngine, SegmentEngine,
};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::stats::fmt_ns;
use pcilt::util::timing::{bench, section, BenchOpts};

fn filter_match(name: &str) -> bool {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    args.is_empty() || args.iter().any(|a| name.contains(a.as_str()))
}

fn boolhash() {
    if !filter_match("boolhash") {
        return;
    }
    section("E4: BoolHash speedup (Figs 5-6; paper claims 6.59x at N=8)");
    let opts = BenchOpts::default();
    let mut rng = Rng::new(11);
    let cases = [(1u32, 1usize, "bool cin=1"), (1, 4, "bool cin=4"), (2, 4, "INT2 cin=4")];
    for (bits, cin, label) in cases {
        let x = Tensor4::random_activations(Shape4::new(1, 96, 96, cin), bits, &mut rng);
        let w = Tensor4::random_weights(Shape4::new(8, 5, 5, cin), 8, &mut rng);
        let geom = ConvGeometry::unit_stride(5, 5);
        let dm = DmEngine::new(w.clone(), geom);
        let y_ref = dm.conv(&x);
        let t_dm = bench("dm", &opts, || dm.conv(&x));
        // A deliberately scalar DM — closer to the kind of baseline the
        // original BoolHash measurement compared against.
        let t_scalar = bench("dm-scalar", &opts, || conv_reference(&x, &w, geom));
        println!(
            "\n[{label} activations]  dm(simd) p50 = {}, dm(scalar) p50 = {}",
            fmt_ns(t_dm.ns_per_iter()),
            fmt_ns(t_scalar.ns_per_iter())
        );
        println!(
            "{:<6} {:>10} {:>10} {:>12} {:>10} {:>12} {:>9} {:>9}",
            "N", "flat p50", "flat", "row p50", "row", "vs-scalar", "segments", "rows/seg"
        );
        for n in [1usize, 2, 4, 8, 16] {
            if n as u32 * bits > 16 {
                continue;
            }
            let seg = SegmentEngine::new(&w, bits, n, geom);
            assert_eq!(seg.conv(&x), y_ref);
            let t = bench("seg", &opts, || seg.conv(&x));
            let row = RowSegmentEngine::new(&w, bits, n, geom);
            assert_eq!(row.conv(&x), y_ref);
            let tr = bench("seg-row", &opts, || row.conv(&x));
            println!(
                "{:<6} {:>10} {:>9.2}x {:>12} {:>9.2}x {:>11.2}x {:>9} {:>9}",
                n,
                fmt_ns(t.ns_per_iter()),
                t_dm.ns_per_iter() / t.ns_per_iter(),
                fmt_ns(tr.ns_per_iter()),
                t_dm.ns_per_iter() / tr.ns_per_iter(),
                t_scalar.ns_per_iter() / tr.ns_per_iter(),
                row.n_segments,
                seg.seg_card
            );
        }
    }
}

fn layout() {
    if !filter_match("layout") {
        return;
    }
    section("E5: Fig 7 layout plans — zero-skipping and reuse");
    let opts = BenchOpts::default();
    let mut rng = Rng::new(13);
    let x = Tensor4::random_activations(Shape4::new(1, 96, 96, 1), 2, &mut rng);
    // A Fig-7-like sparse ring filter: most positions zero.
    let w = Tensor4::from_fn(Shape4::new(4, 5, 5, 1), |_, ky, kx, _| {
        if ky == 0 || ky == 4 || kx == 0 || kx == 4 {
            if (ky + kx) % 2 == 0 {
                2i8
            } else {
                1
            }
        } else {
            0
        }
    });
    let geom = ConvGeometry::unit_stride(5, 5);
    let dm = DmEngine::new(w.clone(), geom);
    let y_ref = dm.conv(&x);
    let t_dm = bench("dm (dense)", &opts, || dm.conv(&x));
    println!("{}", t_dm.report());

    let dense_plan = LayoutPlan::dense(25, 4);
    let dense = LayoutEngine::new(&w, 2, dense_plan.clone(), geom);
    assert_eq!(dense.conv(&x), y_ref);
    let t_dense = bench("layout dense N=4", &opts, || dense.conv(&x));
    println!("{}", t_dense.report());

    // zero-skipping per filter is per-layer here (all filters share the
    // ring support), so one plan works for all output channels:
    let flat: Vec<i32> = {
        let mut f = Vec::new();
        for ky in 0..5 {
            for kx in 0..5 {
                f.push(w.get(0, ky, kx, 0) as i32);
            }
        }
        f
    };
    let skip_plan = LayoutPlan::zero_skipping(&flat, 4);
    let skip = LayoutEngine::new(&w, 2, skip_plan.clone(), geom);
    assert_eq!(skip.conv(&x), y_ref);
    let t_skip = bench("layout zero-skip N=4", &opts, || skip.conv(&x));
    println!("{}", t_skip.report());
    println!(
        "positions processed: dense {} -> skip {} ({}/25 non-zero); \
         speedup over dense layout: {:.2}x",
        dense_plan.work(),
        skip_plan.work(),
        flat.iter().filter(|&&v| v != 0).count(),
        t_dense.ns_per_iter() / t_skip.ns_per_iter()
    );

    // Basic PCILT for context.
    let pc = PciltEngine::new(&w, 2, geom);
    let t_pc = bench("pcilt (per-position)", &opts, || pc.conv(&x));
    println!("{}", t_pc.report());
}

fn main() {
    boolhash();
    layout();
}
