//! E11 — serving benchmarks: the coordinator under Poisson and closed-loop
//! load, across engines (native PCILT / native DM / PJRT artifact), plus a
//! batching-policy sweep. Requires `make artifacts` for the `hlo` rows;
//! native rows run regardless.

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use pcilt::coordinator::{
    run_closed_loop, run_poisson, BackendSpec, NativeEngineKind, Server, ServerOpts,
};
use pcilt::model::random_params;
use pcilt::runtime::ArtifactBundle;
use pcilt::util::prng::Rng;
use pcilt::util::stats::fmt_ns;

fn specs() -> Vec<(String, BackendSpec)> {
    let mut out = Vec::new();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactBundle::load(&dir) {
        Ok(bundle) => {
            out.push((
                "native-pcilt".into(),
                BackendSpec::Native {
                    params: bundle.params.clone(),
                    engine: NativeEngineKind::Pcilt,
                },
            ));
            out.push((
                "native-dm".into(),
                BackendSpec::Native {
                    params: bundle.params.clone(),
                    engine: NativeEngineKind::Dm,
                },
            ));
            out.push((
                "native-segment2".into(),
                BackendSpec::Native {
                    params: bundle.params.clone(),
                    engine: NativeEngineKind::Segment { seg_n: 2 },
                },
            ));
            out.push((
                "hlo-pcilt".into(),
                BackendSpec::Hlo {
                    bundle,
                    engine: "pcilt".into(),
                },
            ));
        }
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); benching random-weight native engines");
            let mut rng = Rng::new(1);
            let params = random_params(4, &mut rng);
            out.push((
                "native-pcilt".into(),
                BackendSpec::Native {
                    params: params.clone(),
                    engine: NativeEngineKind::Pcilt,
                },
            ));
            out.push((
                "native-dm".into(),
                BackendSpec::Native {
                    params,
                    engine: NativeEngineKind::Dm,
                },
            ));
        }
    }
    out
}

fn main() {
    let opts = ServerOpts {
        workers: 4,
        max_batch: 8,
        batch_deadline: Duration::from_micros(2_000),
        queue_capacity: 2048,
    };

    println!("## E11a: open-loop Poisson (2000 rps offered, 3000 requests)");
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>12} {:>8}",
        "engine", "p50", "p99", "tput rps", "mean batch", "shed"
    );
    for (name, spec) in specs() {
        let server = Arc::new(Server::start(spec, &opts).expect("server start"));
        server.warmup(8, 16).expect("warmup");
        let report = run_poisson(&server, 2000.0, 3000, 16, 4, 0xAB);
        let m = server.metrics();
        println!(
            "{:<16} {:>10} {:>10} {:>10.0} {:>12.2} {:>8}",
            name,
            fmt_ns(m.p50_latency_ns),
            fmt_ns(m.p99_latency_ns),
            m.throughput_rps,
            m.mean_batch_size,
            report.rejected
        );
    }

    println!("\n## E11b: closed-loop peak throughput (8 clients x 400 reqs)");
    println!(
        "{:<16} {:>12} {:>10} {:>10}",
        "engine", "tput rps", "p50", "p99"
    );
    for (name, spec) in specs() {
        let server = Arc::new(Server::start(spec, &opts).expect("server start"));
        server.warmup(8, 16).expect("warmup");
        let report = run_closed_loop(&server, 8, 400, 16, 4, 0xCD);
        let m = server.metrics();
        println!(
            "{:<16} {:>12.0} {:>10} {:>10}",
            name,
            report.accepted as f64 / report.wall_s,
            fmt_ns(m.p50_latency_ns),
            fmt_ns(m.p99_latency_ns),
        );
    }

    println!("\n## E11c: batching policy sweep (native-pcilt, closed loop)");
    println!(
        "{:<22} {:>12} {:>10} {:>12}",
        "policy", "tput rps", "p99", "mean batch"
    );
    let base_spec = specs().remove(0).1;
    for (max_batch, deadline_us) in [(1usize, 0u64), (4, 500), (8, 2_000), (16, 5_000)] {
        let server = Arc::new(
            Server::start(
                base_spec.clone(),
                &ServerOpts {
                    workers: 4,
                    max_batch,
                    batch_deadline: Duration::from_micros(deadline_us),
                    queue_capacity: 2048,
                },
            )
            .expect("server start"),
        );
        server.warmup(8, 16).expect("warmup");
        let report = run_closed_loop(&server, 8, 300, 16, 4, 0xEF);
        let m = server.metrics();
        println!(
            "{:<22} {:>12.0} {:>10} {:>12.2}",
            format!("batch<={max_batch} ddl={deadline_us}us"),
            report.accepted as f64 / report.wall_s,
            fmt_ns(m.p99_latency_ns),
            m.mean_batch_size
        );
    }
}
