//! E11 — serving benchmarks: the coordinator under Poisson and closed-loop
//! load, across engines (native PCILT / native DM / PJRT artifact), plus a
//! batching-policy sweep and the multi-model registry scenario (2 models
//! sharing a backbone vs 2 independent models — table bytes + dedup hits).
//! Requires `make artifacts` for the `hlo` rows; native rows run
//! regardless. With `PCILT_BENCH_JSON` set, the multi-model results land
//! in that file (`BENCH_serving.json` in CI).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use pcilt::config::{EngineKind, ModelConfig};
use pcilt::coordinator::{
    run_closed_loop, run_poisson, run_poisson_models, BackendSpec, ModelRegistry,
    NativeEngineKind, Server, ServerOpts,
};
use pcilt::model::random_params;
use pcilt::pcilt::store::{TableStore, TableStoreStats};
use pcilt::runtime::ArtifactBundle;
use pcilt::util::prng::Rng;
use pcilt::util::stats::fmt_ns;

/// `PCILT_BENCH_QUICK=1` shrinks request counts (CI smoke runs).
fn quick() -> bool {
    std::env::var("PCILT_BENCH_QUICK").is_ok()
}

fn specs() -> Vec<(String, BackendSpec)> {
    let mut out = Vec::new();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match ArtifactBundle::load(&dir) {
        Ok(bundle) => {
            out.push((
                "native-pcilt".into(),
                BackendSpec::native(bundle.params.clone(), NativeEngineKind::Pcilt),
            ));
            out.push((
                "native-dm".into(),
                BackendSpec::native(bundle.params.clone(), NativeEngineKind::Dm),
            ));
            out.push((
                "native-segment2".into(),
                BackendSpec::native(bundle.params.clone(), NativeEngineKind::Segment { seg_n: 2 }),
            ));
            out.push(("hlo-pcilt".into(), BackendSpec::hlo(bundle, "pcilt")));
        }
        Err(e) => {
            eprintln!("artifacts unavailable ({e}); benching random-weight native engines");
            let mut rng = Rng::new(1);
            let params = random_params(4, &mut rng);
            out.push((
                "native-pcilt".into(),
                BackendSpec::native(params.clone(), NativeEngineKind::Pcilt),
            ));
            out.push((
                "native-dm".into(),
                BackendSpec::native(params, NativeEngineKind::Dm),
            ));
        }
    }
    out
}

fn model_cfg(name: &str, seed: u64, head_seed: Option<u64>) -> ModelConfig {
    ModelConfig {
        name: name.to_string(),
        engine: EngineKind::Pcilt,
        act_bits: 4,
        seed,
        head_seed,
        ..ModelConfig::default()
    }
}

/// One multi-model scenario: start a fresh registry over a private store,
/// drive mixed Poisson traffic, return (store stats, achieved rps).
fn run_multi_scenario(models: &[ModelConfig], requests: usize) -> (TableStoreStats, f64) {
    let opts = ServerOpts {
        workers: 2,
        max_batch: 8,
        batch_deadline: Duration::from_micros(2_000),
        queue_capacity: 2048,
    };
    let store = Arc::new(TableStore::new());
    let registry =
        ModelRegistry::start_with_store(models, &opts, store.clone()).expect("registry start");
    let report = run_poisson_models(&registry, 2000.0, requests, 0x51);
    let stats = store.stats();
    let tput = report.accepted as f64 / report.wall_s;
    registry.shutdown();
    (stats, tput)
}

fn main() {
    let (poisson_reqs, closed_per_client, sweep_per_client, multi_reqs) = if quick() {
        (300, 40, 30, 200)
    } else {
        (3000, 400, 300, 2000)
    };
    let opts = ServerOpts {
        workers: 4,
        max_batch: 8,
        batch_deadline: Duration::from_micros(2_000),
        queue_capacity: 2048,
    };

    println!("## E11a: open-loop Poisson (2000 rps offered, {poisson_reqs} requests)");
    println!(
        "{:<16} {:>10} {:>10} {:>10} {:>12} {:>8}",
        "engine", "p50", "p99", "tput rps", "mean batch", "shed"
    );
    for (name, spec) in specs() {
        let server = Arc::new(Server::start(spec, &opts).expect("server start"));
        server.warmup(8, 16).expect("warmup");
        let report = run_poisson(&server, 2000.0, poisson_reqs, 16, 4, 0xAB);
        let m = server.metrics();
        println!(
            "{:<16} {:>10} {:>10} {:>10.0} {:>12.2} {:>8}",
            name,
            fmt_ns(m.p50_latency_ns),
            fmt_ns(m.p99_latency_ns),
            m.throughput_rps,
            m.mean_batch_size,
            report.rejected
        );
    }

    println!("\n## E11b: closed-loop peak throughput (8 clients x {closed_per_client} reqs)");
    println!(
        "{:<16} {:>12} {:>10} {:>10}",
        "engine", "tput rps", "p50", "p99"
    );
    for (name, spec) in specs() {
        let server = Arc::new(Server::start(spec, &opts).expect("server start"));
        server.warmup(8, 16).expect("warmup");
        let report = run_closed_loop(&server, 8, closed_per_client, 16, 4, 0xCD);
        let m = server.metrics();
        println!(
            "{:<16} {:>12.0} {:>10} {:>10}",
            name,
            report.accepted as f64 / report.wall_s,
            fmt_ns(m.p50_latency_ns),
            fmt_ns(m.p99_latency_ns),
        );
    }

    println!("\n## E11c: batching policy sweep (native-pcilt, closed loop)");
    println!(
        "{:<22} {:>12} {:>10} {:>12}",
        "policy", "tput rps", "p99", "mean batch"
    );
    let base_spec = specs().remove(0).1;
    for (max_batch, deadline_us) in [(1usize, 0u64), (4, 500), (8, 2_000), (16, 5_000)] {
        let server = Arc::new(
            Server::start(
                base_spec.clone(),
                &ServerOpts {
                    workers: 4,
                    max_batch,
                    batch_deadline: Duration::from_micros(deadline_us),
                    queue_capacity: 2048,
                },
            )
            .expect("server start"),
        );
        server.warmup(8, 16).expect("warmup");
        let report = run_closed_loop(&server, 8, sweep_per_client, 16, 4, 0xEF);
        let m = server.metrics();
        println!(
            "{:<22} {:>12.0} {:>10} {:>12.2}",
            format!("batch<={max_batch} ddl={deadline_us}us"),
            report.accepted as f64 / report.wall_s,
            fmt_ns(m.p99_latency_ns),
            m.mean_batch_size
        );
    }

    // E11d: the multi-model registry. Two models with a shared backbone
    // (same conv seed, different heads) vs two fully independent models —
    // the shared fleet must hold roughly half the table bytes and record
    // cross-model dedup hits.
    println!("\n## E11d: multi-model registry ({multi_reqs} mixed requests per scenario)");
    let shared_models = [model_cfg("base", 7, None), model_cfg("tuned", 7, Some(99))];
    let indep_models = [model_cfg("m1", 7, None), model_cfg("m2", 8, None)];
    let (shared, shared_tput) = run_multi_scenario(&shared_models, multi_reqs);
    let (indep, indep_tput) = run_multi_scenario(&indep_models, multi_reqs);
    println!(
        "{:<26} {:>10} {:>14} {:>8} {:>12}",
        "scenario", "entries", "table bytes", "dedups", "tput rps"
    );
    for (label, s, tput) in [
        ("2 models, shared backbone", &shared, shared_tput),
        ("2 independent models", &indep, indep_tput),
    ] {
        println!(
            "{:<26} {:>10} {:>14.0} {:>8} {:>12.0}",
            label, s.entries, s.bytes, s.cross_model_dedup, tput
        );
    }
    println!(
        "shared-backbone fleet holds {:.2}x the table bytes of the independent fleet",
        shared.bytes / indep.bytes
    );

    if let Ok(path) = std::env::var("PCILT_BENCH_JSON") {
        write_bench_json(&path, &shared, shared_tput, &indep, indep_tput);
        println!("wrote {path}");
    }
}

/// Hand-rolled JSON (no serde offline); names are plain ASCII.
fn write_bench_json(
    path: &str,
    shared: &TableStoreStats,
    shared_tput: f64,
    indep: &TableStoreStats,
    indep_tput: f64,
) {
    // `goodput_rps` = completed responses per second — the same name the
    // net tier's loadtest emitter uses, so the two serving JSONs agree.
    let scenario = |s: &TableStoreStats, tput: f64| {
        format!(
            "{{\"entries\": {}, \"table_bytes\": {:.0}, \"cross_model_dedup\": {}, \
             \"builds\": {}, \"goodput_rps\": {:.1}}}",
            s.entries, s.bytes, s.cross_model_dedup, s.builds, tput
        )
    };
    let json = format!(
        "{{\n  \"bench\": \"bench_serving/multi_model\",\n  \
         \"shared_backbone\": {},\n  \"independent\": {},\n  \
         \"table_bytes_ratio\": {:.3}\n}}\n",
        scenario(shared, shared_tput),
        scenario(indep, indep_tput),
        shared.bytes / indep.bytes,
    );
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("failed to write {path}: {e}");
    }
}
