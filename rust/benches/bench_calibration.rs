//! Calibration-planner benchmark: what a measured plan costs relative to
//! the analytic one. `calibrate` micro-benches every feasible candidate
//! (expensive, run once per host), while replanning against the saved
//! per-host database (`--calibrated`) must stay as cheap as the pure
//! analytic plan. Results land in the JSON file named by
//! `PCILT_BENCH_JSON` (`BENCH_calibration.json` in CI).

use std::sync::Arc;

use pcilt::model::{layer_specs, random_params};
use pcilt::pcilt::planner::{EnginePlanner, PlannerPolicy};
use pcilt::pcilt::CalibrationDb;
use pcilt::util::prng::Rng;
use pcilt::util::timing::{bench, section, BenchOpts};

fn bench_opts() -> BenchOpts {
    if std::env::var("PCILT_BENCH_QUICK").is_ok() {
        BenchOpts::quick()
    } else {
        BenchOpts::default()
    }
}

fn main() {
    section("Calibration planner: analytic plan vs calibrate vs calibrated replan");
    let opts = bench_opts();
    let mut rng = Rng::new(42);
    let params = random_params(4, &mut rng);
    let [s1, s2] = layer_specs(&params, 8);

    let analytic_planner = EnginePlanner::new(PlannerPolicy::default());
    let analytic = bench("analytic plan (2 layers)", &opts, || {
        (
            analytic_planner.plan_layer(&s1, Some(&params.w1)),
            analytic_planner.plan_layer(&s2, Some(&params.w2)),
        )
    });
    println!("{}", analytic.report());

    // One calibration pass: micro-bench every feasible candidate and
    // record the timings (this is what `pcilt plan --calibrate` runs).
    let mut db = CalibrationDb::with_host("bench-host");
    let t0 = std::time::Instant::now();
    analytic_planner.calibrate_recording(&s1, &params.w1, 0xCA1, &mut db);
    analytic_planner.calibrate_recording(&s2, &params.w2, 0xCA2, &mut db);
    let calibrate_ns = t0.elapsed().as_nanos() as f64;
    println!(
        "calibrate (2 layers, {} timings recorded): {:.1} ms one-off",
        db.len(),
        calibrate_ns / 1e6
    );

    // Persist + reload through the checksummed artifact, then replan with
    // measured overrides — the `--calibrated` hot path.
    let dir = std::env::temp_dir().join(format!("pcilt-bench-cal-{}", std::process::id()));
    db.save(&dir).expect("calibration db saves");
    let db_bytes = CalibrationDb::artifact_bytes(&dir);
    let loaded = CalibrationDb::load_for_host(&dir, "bench-host").expect("roundtrip");
    assert_eq!(loaded, db, "persistence must be lossless");
    let entries = loaded.len();
    let calibrated_planner =
        EnginePlanner::new(PlannerPolicy::default()).with_calibration(Arc::new(loaded));
    let calibrated = bench("calibrated replan (2 layers)", &opts, || {
        (
            calibrated_planner.plan_layer(&s1, Some(&params.w1)),
            calibrated_planner.plan_layer(&s2, Some(&params.w2)),
        )
    });
    println!("{}", calibrated.report());
    let (p1, p2) = (
        calibrated_planner.plan_layer(&s1, Some(&params.w1)),
        calibrated_planner.plan_layer(&s2, Some(&params.w2)),
    );
    assert!(
        p1.candidates.iter().any(|c| c.measured.is_some())
            && p2.candidates.iter().any(|c| c.measured.is_some()),
        "calibrated replans must carry measured overrides"
    );
    println!(
        "replan overhead vs analytic: {:.2}x ({} db entries, {} bytes on disk)",
        calibrated.ns_per_iter() / analytic.ns_per_iter(),
        entries,
        db_bytes
    );
    let _ = std::fs::remove_dir_all(&dir);

    if let Ok(path) = std::env::var("PCILT_BENCH_JSON") {
        let json = format!(
            "{{\n  \"bench\": \"bench_calibration/planner\",\n  \
             \"analytic_plan_p50_ns\": {:.1},\n  \"calibrated_plan_p50_ns\": {:.1},\n  \
             \"calibrate_once_ns\": {calibrate_ns:.1},\n  \"db_entries\": {entries},\n  \
             \"db_bytes\": {db_bytes}\n}}\n",
            analytic.ns_per_iter(),
            calibrated.ns_per_iter(),
        );
        if let Err(e) = std::fs::write(&path, json) {
            eprintln!("could not write {path}: {e}");
        } else {
            println!("wrote {path}");
        }
    }
}
