//! Shared helpers for the integration suites: the golden-vector fixture
//! format used by `network_stack.rs` and `fused_stack.rs`.
//!
//! A fixture (`tests/data/golden_<name>.bin`) freezes one conformance
//! case: conv + dense weights, an input code tensor and the expected
//! logits — produced *outside* the crate (`python/tools/gen_golden.py`
//! mirrors the integer pipeline with numpy), so conformance no longer
//! rests solely on the in-process DM reference agreeing with itself. The
//! stage graphs live in [`golden_spec`]; the generator script and this
//! module must agree on them (both carry the layout comment).
//!
//! Binary layout (all little-endian):
//!
//! ```text
//! magic "PGLD" | u32 version = 1
//! u32 n_convs | per conv: u32 o,h,w,i then o*h*w*i weight bytes (i8)
//! u32 dense_len | dense weight bytes (i8)
//! u32 b,h,w,c | b*h*w*c input code bytes (u8)
//! u32 rows, classes | rows*classes expected logits (i32)
//! ```

// Each integration-test crate compiles this module independently and uses
// a different subset of it; unused-item lints would otherwise fire
// per-crate under `clippy -D warnings`.
#![allow(dead_code)]

use std::path::PathBuf;

use pcilt::model::{EngineChoice, NetworkSpec, NetworkWeights, StageSpec};
use pcilt::tensor::{Shape4, Tensor4};

/// Every checked-in fixture name.
pub const GOLDEN_FIXTURES: &[&str] = &["g2_pool_floor", "g4_odd_maps", "g8_deep_pool"];

/// The frozen stage graph of a fixture. Scales are dyadic rationals
/// (exact in f32 *and* f64) so the generator's numpy floats and the
/// crate's f32 literals denote identical values.
pub fn golden_spec(name: &str, engine: EngineChoice) -> NetworkSpec {
    let conv = |out_ch: usize| StageSpec::Conv {
        out_ch,
        kernel: 3,
        stride: 1,
        engine,
    };
    match name {
        // 2-bit codes, even maps, a strict pool and a floored (3x3 -> 1x1)
        // pool — the truncating-boundary case the bugfix pins.
        "g2_pool_floor" => NetworkSpec {
            act_bits: 2,
            img: 12,
            in_ch: 1,
            stages: vec![
                conv(4),
                StageSpec::Requantize { scale: 0.0625 },
                StageSpec::MaxPool { k: 2, floor: false }, // 10 -> 5
                conv(6),
                StageSpec::Requantize { scale: 0.09375 },
                StageSpec::MaxPool { k: 2, floor: true }, // 3 -> 1 (floor)
                StageSpec::Dense { classes: 5 },
            ],
        },
        // 4-bit codes, odd maps end-to-end, two input channels, no pool.
        "g4_odd_maps" => NetworkSpec {
            act_bits: 4,
            img: 9,
            in_ch: 2,
            stages: vec![
                conv(3),
                StageSpec::Requantize { scale: 0.03125 },
                conv(5),
                StageSpec::Requantize { scale: 0.046875 },
                StageSpec::Dense { classes: 4 },
            ],
        },
        // 8-bit codes (the widest u8 cardinality), two pooled chains.
        "g8_deep_pool" => NetworkSpec {
            act_bits: 8,
            img: 10,
            in_ch: 1,
            stages: vec![
                conv(2),
                StageSpec::Requantize { scale: 0.00390625 },
                StageSpec::MaxPool { k: 2, floor: false }, // 8 -> 4
                conv(3),
                StageSpec::Requantize { scale: 0.015625 },
                StageSpec::MaxPool { k: 2, floor: false }, // 2 -> 1
                StageSpec::Dense { classes: 3 },
            ],
        },
        other => panic!("unknown golden fixture '{other}'"),
    }
}

/// One loaded fixture: weights, input codes and the expected logits.
pub struct GoldenCase {
    pub weights: NetworkWeights,
    pub input: Tensor4<u8>,
    pub logits: Vec<Vec<i32>>,
}

/// `tests/data/golden_<name>.bin` under the crate root.
pub fn golden_path(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests/data")
        .join(format!("golden_{name}.bin"))
}

struct Reader {
    buf: Vec<u8>,
    pos: usize,
}

impl Reader {
    fn bytes(&mut self, n: usize) -> &[u8] {
        assert!(self.pos + n <= self.buf.len(), "golden fixture truncated");
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        s
    }

    fn u32(&mut self) -> u32 {
        let b = self.bytes(4);
        u32::from_le_bytes([b[0], b[1], b[2], b[3]])
    }

    fn i8_vec(&mut self, n: usize) -> Vec<i8> {
        self.bytes(n).iter().map(|&b| b as i8).collect()
    }
}

/// Parse a checked-in fixture. Panics (with context) on any malformation —
/// a broken fixture is a repo error, not a runtime condition.
pub fn load_golden(name: &str) -> GoldenCase {
    let path = golden_path(name);
    let buf = std::fs::read(&path)
        .unwrap_or_else(|e| panic!("reading golden fixture {}: {e}", path.display()));
    let mut r = Reader { buf, pos: 0 };
    assert_eq!(r.bytes(4), b"PGLD", "bad magic in {name}");
    assert_eq!(r.u32(), 1, "unsupported golden version in {name}");
    let n_convs = r.u32() as usize;
    let mut convs = Vec::with_capacity(n_convs);
    for _ in 0..n_convs {
        let (o, h, w, i) = (r.u32() as usize, r.u32() as usize, r.u32() as usize, r.u32() as usize);
        let data = r.i8_vec(o * h * w * i);
        convs.push(Tensor4::from_vec(Shape4::new(o, h, w, i), data));
    }
    let dense_len = r.u32() as usize;
    let dense = r.i8_vec(dense_len);
    let (b, h, w, c) = (r.u32() as usize, r.u32() as usize, r.u32() as usize, r.u32() as usize);
    let input = Tensor4::from_vec(Shape4::new(b, h, w, c), r.bytes(b * h * w * c).to_vec());
    let (rows, classes) = (r.u32() as usize, r.u32() as usize);
    let mut logits = Vec::with_capacity(rows);
    for _ in 0..rows {
        logits.push(
            r.bytes(classes * 4)
                .chunks_exact(4)
                .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect(),
        );
    }
    assert_eq!(r.pos, r.buf.len(), "trailing bytes in golden fixture {name}");
    GoldenCase {
        weights: NetworkWeights { convs, dense },
        input,
        logits,
    }
}

/// Serialize a fixture (the `#[ignore]` regenerator in `fused_stack.rs`
/// uses this to refresh expected logits in place).
pub fn write_golden(name: &str, case: &GoldenCase) {
    let mut out: Vec<u8> = Vec::new();
    out.extend_from_slice(b"PGLD");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(case.weights.convs.len() as u32).to_le_bytes());
    for w in &case.weights.convs {
        let s = w.shape();
        for d in [s.n, s.h, s.w, s.c] {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        out.extend(w.data().iter().map(|&v| v as u8));
    }
    out.extend_from_slice(&(case.weights.dense.len() as u32).to_le_bytes());
    out.extend(case.weights.dense.iter().map(|&v| v as u8));
    let s = case.input.shape();
    for d in [s.n, s.h, s.w, s.c] {
        out.extend_from_slice(&(d as u32).to_le_bytes());
    }
    out.extend_from_slice(case.input.data());
    out.extend_from_slice(&(case.logits.len() as u32).to_le_bytes());
    let classes = case.logits.first().map(|l| l.len()).unwrap_or(0);
    out.extend_from_slice(&(classes as u32).to_le_bytes());
    for row in &case.logits {
        assert_eq!(row.len(), classes);
        for &v in row {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }
    let path = golden_path(name);
    std::fs::create_dir_all(path.parent().unwrap()).unwrap();
    std::fs::write(&path, out)
        .unwrap_or_else(|e| panic!("writing golden fixture {}: {e}", path.display()));
}
