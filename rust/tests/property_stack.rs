//! Cross-module property tests: the invariants DESIGN.md §7 lists, checked
//! with the in-tree propcheck harness at larger scales than the per-module
//! unit tests.

use std::sync::Arc;
use std::time::Duration;

use pcilt::coordinator::{BackendSpec, BoundedQueue, NativeEngineKind, Server, ServerOpts};
use pcilt::model::{random_params, EngineChoice, QuantCnn};
use pcilt::pcilt::dm::conv_reference;
use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::{
    ConvFunc, DmEngine, LayoutEngine, LayoutPlan, PciltEngine, SegmentEngine, SharedEngine,
};
use pcilt::quant::Quantizer;
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::propcheck::forall;

/// Every engine in the crate computes the same convolution. One property to
/// rule them all.
#[test]
fn all_engines_equal_reference() {
    forall("all engines == naive reference", 25, |g| {
        let mut rng = Rng::new(g.i64(0, i64::MAX / 2) as u64);
        let bits = *rng.choose(&[1u32, 2, 4]);
        let (kh, kw) = *rng.choose(&[(3usize, 3usize), (5, 5)]);
        let ic = rng.range_i64(1, 3) as usize;
        let oc = rng.range_i64(1, 4) as usize;
        let h = kh + rng.range_i64(0, 6) as usize;
        let wd = kw + rng.range_i64(0, 6) as usize;
        let x = Tensor4::random_activations(Shape4::new(1, h, wd, ic), bits, &mut rng);
        let w = Tensor4::random_weights(Shape4::new(oc, kh, kw, ic), 8, &mut rng);
        let geom = ConvGeometry::unit_stride(kh, kw);
        let expect = conv_reference(&x, &w, geom);

        assert_eq!(DmEngine::new(w.clone(), geom).conv(&x), expect, "dm");
        assert_eq!(PciltEngine::new(&w, bits, geom).conv(&x), expect, "pcilt");
        assert_eq!(SharedEngine::new(&w, bits, geom).conv(&x), expect, "shared");
        let seg_n = *rng.choose(&[1usize, 2, 4]);
        if seg_n as u32 * bits <= 12 {
            assert_eq!(
                SegmentEngine::new(&w, bits, seg_n, geom).conv(&x),
                expect,
                "segment{seg_n}"
            );
        }
        let positions = kh * kw * ic;
        let plan = LayoutPlan::dense(positions, *rng.choose(&[2usize, 3, 5]));
        assert_eq!(LayoutEngine::new(&w, bits, plan, geom).conv(&x), expect, "layout");
    });
}

/// PCILT with a custom function == DM over pre-transformed activations,
/// when the function factors as w * t(a).
#[test]
fn codebook_factorization_property() {
    forall("codebook pcilt == dm over decoded acts", 20, |g| {
        let mut rng = Rng::new(g.i64(0, i64::MAX / 2) as u64);
        // integer codebook so both paths are exact
        let codes: Vec<f32> = (0..8).map(|i| (i * i) as f32).collect();
        let f = ConvFunc::Codebook {
            codes: codes.clone(),
        };
        let x = Tensor4::random_activations(Shape4::new(1, 6, 6, 2), 3, &mut rng);
        let w = Tensor4::random_weights(Shape4::new(2, 3, 3, 2), 5, &mut rng);
        let geom = ConvGeometry::unit_stride(3, 3);
        let via_table = PciltEngine::with_func(&w, 3, geom, &f).conv(&x);
        // decode activations then run plain DM — here decoded values are
        // squares (0..49), still u8-representable
        let decoded = x.map(|a| codes[a as usize] as u8);
        let via_dm = DmEngine::new(w, geom).conv(&decoded);
        assert_eq!(via_table, via_dm);
    });
}

/// Quantize→dequantize→quantize is stable (idempotence of the codec pair).
#[test]
fn quantizer_idempotence() {
    forall("quantize is idempotent after one roundtrip", 200, |g| {
        let bits = g.one_of(&[2u32, 4, 8]);
        let max = g.f32(0.5, 8.0);
        let q = Quantizer::symmetric(max, bits);
        let x = g.f32(-2.0 * max, 2.0 * max);
        let once = q.quantize(x);
        let twice = q.quantize(q.dequantize(once));
        assert_eq!(once, twice);
    });
}

/// The queue conserves requests under adversarial batch geometry.
#[test]
fn queue_conserves_under_random_batching() {
    forall("queue conservation", 15, |g| {
        let cap = g.usize(4, 64);
        let n = g.usize(1, 200);
        let max_batch = g.usize(1, 16);
        let q = BoundedQueue::new(cap);
        let mut accepted = Vec::new();
        let mut popped = Vec::new();
        for i in 0..n {
            match q.push(i) {
                Ok(()) => accepted.push(i),
                Err(_) => {
                    // drain a bit and retry once
                    if let Some(b) = q.pop_batch(max_batch, Duration::ZERO) {
                        popped.extend(b);
                    }
                    if q.push(i).is_ok() {
                        accepted.push(i);
                    }
                }
            }
        }
        q.close();
        while let Some(b) = q.pop_batch(max_batch, Duration::ZERO) {
            popped.extend(b);
        }
        assert_eq!(popped.len(), accepted.len());
        assert_eq!(popped, accepted, "FIFO order violated");
    });
}

/// Server answers are independent of batch composition: the same image
/// always yields the same logits whatever else it is batched with.
#[test]
fn serving_batch_composition_invariance() {
    let mut rng = Rng::new(99);
    let params = random_params(4, &mut rng);
    let native = QuantCnn::new(params.clone(), EngineChoice::Pcilt);
    let server = Arc::new(
        Server::start(
            BackendSpec::native(params, NativeEngineKind::Pcilt),
            &ServerOpts {
                workers: 2,
                max_batch: 8,
                batch_deadline: Duration::from_micros(500),
                queue_capacity: 512,
            },
        )
        .unwrap(),
    );
    let probe = Tensor4::random_activations(Shape4::new(1, 16, 16, 1), 4, &mut rng);
    let expect = native.forward(&probe).remove(0);
    // Interleave the probe with random noise traffic from another thread.
    let noise_server = Arc::clone(&server);
    let noise = std::thread::spawn(move || {
        let mut rng = Rng::new(123);
        for _ in 0..200 {
            let img = Tensor4::random_activations(Shape4::new(1, 16, 16, 1), 4, &mut rng);
            let _ = noise_server.infer_blocking(img);
        }
    });
    for _ in 0..50 {
        let resp = server.infer_blocking(probe.clone()).unwrap();
        assert_eq!(resp.logits, expect, "batch composition changed an answer");
    }
    noise.join().unwrap();
}

/// Requant codes are monotone in the accumulator (order preservation the
/// max-pool-on-codes optimization relies on).
#[test]
fn requant_monotonicity() {
    forall("requant is monotone", 100, |g| {
        let mut rng = Rng::new(g.i64(0, i64::MAX / 2) as u64);
        let params = random_params(4, &mut rng);
        let m = QuantCnn::new(params, EngineChoice::Dm);
        // encode_input is the exposed quantizer; monotone in the input
        let a = g.f32(0.0, 1.0);
        let b = g.f32(0.0, 1.0);
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        let t = Tensor4::from_vec(Shape4::new(1, 1, 1, 2), vec![lo, hi]);
        let codes = m.encode_input(&t);
        assert!(codes.data()[0] <= codes.data()[1]);
    });
}
