//! Calibration-database integration tests: the measured-planner stack
//! end to end. A `calibrate_recording` run persists per-host timings
//! through the checksummed `calibration.bin` artifact; reloading them
//! overrides analytic scores and can flip an engine choice; and every
//! rejection path (missing, truncated, corrupted, stale-host) falls back
//! cleanly instead of poisoning a plan — the PR's acceptance criteria.

use std::path::PathBuf;
use std::sync::Arc;

use pcilt::model::{layer_specs, random_params};
use pcilt::pcilt::calibration::{CAL_BIN_FILE, CAL_MANIFEST_FILE};
use pcilt::pcilt::engine::ConvGeometry;
use pcilt::pcilt::planner::{EngineId, EnginePlanner, LayerSpec, PlannerPolicy};
use pcilt::pcilt::{CalIoError, CalibrationDb};
use pcilt::tensor::Shape4;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pcilt_cal_stack_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn sample_spec() -> LayerSpec {
    LayerSpec {
        geom: ConvGeometry::unit_stride(5, 5),
        in_ch: 1,
        out_ch: 8,
        act_bits: 1,
        weight_bits: 8,
        input: Shape4::new(1, 64, 64, 1),
    }
}

/// End to end: calibrate the sample model recording into a db, persist,
/// reload, and verify the reloaded planner reproduces the measured
/// choice without re-benchmarking.
#[test]
fn calibrate_persist_reload_reproduces_choice() {
    let dir = temp_dir("roundtrip");
    let params = random_params(2, &mut pcilt::util::prng::Rng::new(42));
    let [s1, s2] = layer_specs(&params, 4);
    let planner = EnginePlanner::new(PlannerPolicy::default());
    let mut db = CalibrationDb::with_host("ci-host");
    let p1 = planner.calibrate_recording(&s1, &params.w1, 0xCA1, &mut db);
    let p2 = planner.calibrate_recording(&s2, &params.w2, 0xCA2, &mut db);
    assert!(!db.is_empty(), "calibration must record measurements");
    db.save(&dir).unwrap();

    let loaded = CalibrationDb::load_for_host(&dir, "ci-host").unwrap();
    assert_eq!(loaded, db, "persistence roundtrip must be lossless");
    let replanner =
        EnginePlanner::new(PlannerPolicy::default()).with_calibration(Arc::new(loaded));
    let r1 = replanner.plan_layer(&s1, Some(&params.w1));
    let r2 = replanner.plan_layer(&s2, Some(&params.w2));
    assert_eq!(r1.chosen, p1.chosen, "layer 1 choice must replay from the db");
    assert_eq!(r2.chosen, p2.chosen, "layer 2 choice must replay from the db");
    assert!(r1.chosen_candidate().measured.is_some());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Saving the same database twice produces byte-identical artifacts —
/// the determinism the content-addressed store idiom promises.
#[test]
fn persistence_is_deterministic() {
    let d1 = temp_dir("det_a");
    let d2 = temp_dir("det_b");
    let mut db = CalibrationDb::with_host("ci-host");
    let fp = sample_spec().fingerprint();
    db.record(fp, "pcilt", 1111.0);
    db.record(fp, "dm", 2222.0);
    db.record(fp, "segment(n=4)", 333.5);
    db.save(&d1).unwrap();
    db.save(&d2).unwrap();
    for f in [CAL_BIN_FILE, CAL_MANIFEST_FILE] {
        assert_eq!(
            std::fs::read(d1.join(f)).unwrap(),
            std::fs::read(d2.join(f)).unwrap(),
            "{f} must be byte-identical across saves"
        );
    }
    let _ = std::fs::remove_dir_all(&d1);
    let _ = std::fs::remove_dir_all(&d2);
}

/// A measured override must be able to flip the analytic winner — the
/// whole point of calibrated planning.
#[test]
fn measured_override_flips_engine_choice_through_disk() {
    let dir = temp_dir("flip");
    let spec = sample_spec();
    let analytic = EnginePlanner::new(PlannerPolicy::default()).plan_layer(&spec, None);
    assert_ne!(
        analytic.chosen,
        EngineId::Dm,
        "low-bit large-frame layer must pick a lookup engine analytically"
    );
    // "Measurements" saying DM is fastest on this host.
    let mut db = CalibrationDb::with_host("ci-host");
    db.record(spec.fingerprint(), "dm", 10.0);
    db.record(spec.fingerprint(), analytic.chosen_candidate().label.as_str(), 1.0e9);
    db.save(&dir).unwrap();
    let loaded = Arc::new(CalibrationDb::load_for_host(&dir, "ci-host").unwrap());
    let plan = EnginePlanner::new(PlannerPolicy::default())
        .with_calibration(loaded)
        .plan_layer(&spec, None);
    assert_eq!(plan.chosen, EngineId::Dm, "measured db must flip the choice to DM");
    let report = plan.report();
    assert!(report.contains("meas(ns)"), "report must show the measured column:\n{report}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Missing database: an Io error, distinguishable from corruption, so
/// callers (the `--calibrated` CLI path) warn and plan analytically.
#[test]
fn missing_db_yields_io_error_and_analytic_fallback() {
    let dir = temp_dir("missing");
    assert!(matches!(
        CalibrationDb::load_for_host(&dir, "ci-host"),
        Err(CalIoError::Io(_))
    ));
    // The fallback path: a planner without calibration attached scores
    // analytically and still chooses.
    let plan = EnginePlanner::new(PlannerPolicy::default()).plan_layer(&sample_spec(), None);
    assert!(plan.chosen_candidate().measured.is_none());
}

/// Corrupt payloads (bit flip) and truncated files are rejected with
/// `Corrupt`, never partially loaded.
#[test]
fn corrupt_and_truncated_dbs_are_rejected() {
    let dir = temp_dir("corrupt");
    let mut db = CalibrationDb::with_host("ci-host");
    db.record(sample_spec().fingerprint(), "pcilt", 500.0);
    db.save(&dir).unwrap();
    let clean = std::fs::read(dir.join(CAL_BIN_FILE)).unwrap();

    let mut flipped = clean.clone();
    let mid = flipped.len() / 2;
    flipped[mid] ^= 0x55;
    std::fs::write(dir.join(CAL_BIN_FILE), &flipped).unwrap();
    assert!(matches!(
        CalibrationDb::load_for_host(&dir, "ci-host"),
        Err(CalIoError::Corrupt(_))
    ));

    std::fs::write(dir.join(CAL_BIN_FILE), &clean[..clean.len() - 6]).unwrap();
    assert!(matches!(
        CalibrationDb::load_for_host(&dir, "ci-host"),
        Err(CalIoError::Corrupt(_))
    ));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A database measured on another machine is stale: its nanoseconds do
/// not transfer, so loading for this host must refuse with `StaleHost`.
#[test]
fn stale_host_db_is_rejected_with_both_names() {
    let dir = temp_dir("stale");
    let mut db = CalibrationDb::with_host("build-farm-03");
    db.record(sample_spec().fingerprint(), "pcilt", 500.0);
    db.save(&dir).unwrap();
    match CalibrationDb::load_for_host(&dir, "laptop") {
        Err(CalIoError::StaleHost { stored, current }) => {
            assert_eq!(stored, "build-farm-03");
            assert_eq!(current, "laptop");
        }
        other => panic!("expected StaleHost, got {other:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Artifact accounting: the calibration files count bytes while present
/// and purge cleanly (the `pcilt tables stats`/`purge` contract).
#[test]
fn artifact_bytes_track_save_and_purge() {
    let dir = temp_dir("bytes");
    assert_eq!(CalibrationDb::artifact_bytes(&dir), 0);
    let mut db = CalibrationDb::with_host("ci-host");
    db.record(sample_spec().fingerprint(), "pcilt", 500.0);
    db.save(&dir).unwrap();
    assert!(CalibrationDb::artifact_bytes(&dir) > 0);
    assert!(CalibrationDb::purge(&dir).unwrap());
    assert_eq!(CalibrationDb::artifact_bytes(&dir), 0);
    let _ = std::fs::remove_dir_all(&dir);
}
