//! Socket serving tier end-to-end (ISSUE-9 acceptance): responses served
//! over a real loopback TCP connection must be bit-identical to an
//! in-process `CompiledNetwork::forward` on the same inputs; overload
//! must be signaled with explicit `Overloaded` frames while the
//! dispatcher's in-flight budget stays bounded; the HTTP adapter must
//! answer `/healthz` and `/metrics` on the same port; and a corrupted
//! frame must be survivable — nacked without killing the connection.
//!
//! ISSUE-10 additions: the multi-shard tier must stay bit-identical and
//! exactly-once under concurrent clients across ≥4 loop shards;
//! per-connection rate limits must nack as shed; and the worker
//! autoscaler must scale a pool up under burst and park back down when
//! idle without losing admitted work.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use pcilt::config::{EngineKind, ModelConfig};
use pcilt::coordinator::{ModelRegistry, ServerOpts};
use pcilt::net::proto::{encode_frame, FrameDecoder, FrameKind, WireNack, WireRequest, WireResponse};
use pcilt::net::{NetOpts, NetServer};
use pcilt::pcilt::TableStore;
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;

fn model_cfg(name: &str, seed: u64) -> ModelConfig {
    ModelConfig {
        name: name.to_string(),
        engine: EngineKind::Pcilt,
        act_bits: 4,
        seed,
        ..ModelConfig::default()
    }
}

fn opts() -> ServerOpts {
    ServerOpts {
        workers: 2,
        max_batch: 4,
        batch_deadline: Duration::from_millis(1),
        queue_capacity: 64,
    }
}

/// Boot a two-model registry plus socket tier on an ephemeral port with
/// explicit net options (`addr` is always overridden to an ephemeral one).
fn serve_with(mut net_opts: NetOpts) -> (NetServer, Arc<ModelRegistry>) {
    let store = Arc::new(TableStore::new());
    let registry = Arc::new(
        ModelRegistry::start_with_store(
            &[model_cfg("base", 7), model_cfg("alt", 21)],
            &opts(),
            store,
        )
        .unwrap(),
    );
    net_opts.addr = "127.0.0.1:0".to_string();
    let net = NetServer::start(Arc::clone(&registry), &net_opts).unwrap();
    (net, registry)
}

/// Boot a two-model registry plus socket tier on an ephemeral port.
fn serve(max_inflight: usize) -> (NetServer, Arc<ModelRegistry>) {
    serve_with(NetOpts { max_inflight, ..NetOpts::default() })
}

fn connect(net: &NetServer) -> TcpStream {
    let stream = TcpStream::connect(net.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
}

fn random_codes(rng: &mut Rng, len: usize, act_bits: u32) -> Vec<u8> {
    let mask = ((1u32 << act_bits) - 1) as u8;
    (0..len).map(|_| (rng.next_u32() as u8) & mask).collect()
}

fn send_request(stream: &mut TcpStream, id: u64, model: &str, codes: Vec<u8>) {
    let req = WireRequest {
        id,
        model: model.to_string(),
        h: 16,
        w: 16,
        c: 1,
        codes,
    };
    stream.write_all(&encode_frame(FrameKind::Infer, &req.encode())).unwrap();
}

/// Blocking-read until the decoder yields one frame.
fn recv_frame(stream: &mut TcpStream, dec: &mut FrameDecoder) -> (FrameKind, Vec<u8>) {
    loop {
        if let Some(frame) = dec.next_frame().expect("protocol error from server") {
            return frame;
        }
        let mut buf = [0u8; 4096];
        let n = stream.read(&mut buf).expect("read from server");
        assert!(n > 0, "server closed the connection unexpectedly");
        dec.extend(&buf[..n]);
    }
}

/// The tentpole bit-identity criterion: for both models, logits served
/// over the socket equal `CompiledNetwork::forward` on the same codes,
/// request for request.
#[test]
fn socket_responses_bit_identical_to_in_process_forward() {
    let (net, registry) = serve(16);
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(404);
    for (i, model) in ["base", "alt", "base", "alt", "base", "alt"].iter().enumerate() {
        let entry = registry.model(model).unwrap();
        let standalone = entry
            .spec
            .compile_with_defaults(&entry.weights, &Arc::new(TableStore::new()))
            .unwrap();
        let codes = random_codes(&mut rng, 16 * 16, 4);
        let img = Tensor4::from_vec(Shape4::new(1, 16, 16, 1), codes.clone());
        let expect = standalone.forward(&img);

        send_request(&mut stream, i as u64, model, codes);
        let (kind, body) = recv_frame(&mut stream, &mut dec);
        assert_eq!(kind, FrameKind::Logits, "request {i}");
        let resp = WireResponse::decode(&body).unwrap();
        assert_eq!(resp.id, i as u64, "response must echo the wire id");
        assert_eq!(resp.model, *model);
        assert_eq!(
            resp.logits, expect[0],
            "model {model} request {i}: socket-served logits != in-process forward"
        );
        // Same argmax (incl. tie-breaking) as the serving worker.
        let argmax = expect[0]
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(idx, _)| idx)
            .unwrap();
        assert_eq!(resp.class as usize, argmax);
        assert!(resp.batch_size >= 1);
    }
    drop(stream);
    let c = net.shutdown();
    assert_eq!(c.accepted, 6);
    assert_eq!(c.completed, 6);
    assert_eq!(c.shed, 0);
}

/// Overload: blast one connection with far more requests than the
/// in-flight budget admits. Every request must be answered explicitly
/// (Logits or Overloaded — never silence), the dispatcher's observable
/// in-flight count must never exceed the budget, and the budget must
/// fully release afterwards.
#[test]
fn overload_sheds_explicitly_with_bounded_inflight() {
    const BUDGET: usize = 2;
    const TOTAL: usize = 64;
    let (net, _registry) = serve(BUDGET);
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(99);
    // Send the whole burst before reading anything: admission control has
    // to decide under pressure, not one request at a time.
    let mut burst = Vec::new();
    for i in 0..TOTAL {
        let req = WireRequest {
            id: i as u64,
            model: "base".to_string(),
            h: 16,
            w: 16,
            c: 1,
            codes: random_codes(&mut rng, 16 * 16, 4),
        };
        burst.extend_from_slice(&encode_frame(FrameKind::Infer, &req.encode()));
    }
    stream.write_all(&burst).unwrap();

    let mut completed = 0usize;
    let mut shed = 0usize;
    let mut seen = vec![false; TOTAL];
    for _ in 0..TOTAL {
        // The budget is observable mid-flight and must stay bounded.
        assert!(
            net.dispatcher().inflight("base") <= BUDGET,
            "in-flight exceeded the admission budget"
        );
        match recv_frame(&mut stream, &mut dec) {
            (FrameKind::Logits, body) => {
                let resp = WireResponse::decode(&body).unwrap();
                assert!(!seen[resp.id as usize], "duplicate answer for id {}", resp.id);
                seen[resp.id as usize] = true;
                completed += 1;
            }
            (FrameKind::Overloaded, body) => {
                let nack = WireNack::decode(&body).unwrap();
                assert!(!seen[nack.id as usize], "duplicate answer for id {}", nack.id);
                seen[nack.id as usize] = true;
                assert!(nack.message.contains("budget") || nack.message.contains("bound"));
                shed += 1;
            }
            (kind, _) => panic!("unexpected frame kind {kind:?}"),
        }
    }
    assert_eq!(completed + shed, TOTAL, "every request answered exactly once");
    assert!(completed >= BUDGET, "the admitted prefix must complete");
    assert!(shed > 0, "a {TOTAL}-deep burst over budget {BUDGET} must shed");
    // Budget fully released once everything is answered.
    let t0 = Instant::now();
    while net.dispatcher().inflight("base") != 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "in-flight budget leaked");
        std::thread::sleep(Duration::from_millis(1));
    }
    let c = net.shutdown();
    assert_eq!(c.completed as usize, completed);
    assert_eq!(c.shed as usize, shed);
}

/// The HTTP adapter shares the binary port: `/healthz` answers 200 ok,
/// `/metrics` renders the net counters and per-model series, and unknown
/// paths get a 404 — each on a connection that then closes.
#[test]
fn healthz_and_metrics_served_on_the_same_port() {
    let (net, _registry) = serve(8);
    // Prime one completed request so the metrics move off zero.
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(5);
    send_request(&mut stream, 1, "", random_codes(&mut rng, 16 * 16, 4));
    let (kind, _) = recv_frame(&mut stream, &mut dec);
    assert_eq!(kind, FrameKind::Logits);
    drop(stream);

    let http = |request: &str| -> String {
        let mut s = connect(&net);
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap(); // server closes after answering
        out
    };
    let health = http("GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(health.starts_with("HTTP/1.1 200"), "{health}");
    assert!(health.ends_with("ok\n"), "{health}");

    let metrics = http("GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(metrics.starts_with("HTTP/1.1 200"), "{metrics}");
    for needle in [
        "pcilt_net_accepted 1",
        "pcilt_net_completed 1",
        "pcilt_model_completed{model=\"base\"}",
        "pcilt_model_p999_ns{model=\"alt\"}",
        "pcilt_model_queue_depth{model=\"base\"}",
    ] {
        assert!(metrics.contains(needle), "missing {needle} in:\n{metrics}");
    }

    let missing = http("GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
    net.shutdown();
}

/// A corrupted frame (bad checksum) is nacked and consumed; the same
/// connection then serves a valid request. A broken magic, by contrast,
/// is fatal: the server closes that connection — but keeps serving new
/// ones.
#[test]
fn connection_survives_bad_frame_but_not_bad_magic() {
    let (net, _registry) = serve(8);
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(17);

    // Corrupt the checksum trailer of an otherwise valid frame.
    let req = WireRequest {
        id: 9,
        model: "base".to_string(),
        h: 16,
        w: 16,
        c: 1,
        codes: random_codes(&mut rng, 16 * 16, 4),
    };
    let mut bad = encode_frame(FrameKind::Infer, &req.encode());
    let last = bad.len() - 1;
    bad[last] ^= 0xFF;
    stream.write_all(&bad).unwrap();
    let (kind, body) = recv_frame(&mut stream, &mut dec);
    assert_eq!(kind, FrameKind::Error, "checksum mismatch must be nacked");
    let nack = WireNack::decode(&body).unwrap();
    assert!(nack.message.contains("checksum"), "{}", nack.message);

    // Same connection, valid frame: still served.
    send_request(&mut stream, 10, "base", random_codes(&mut rng, 16 * 16, 4));
    let (kind, body) = recv_frame(&mut stream, &mut dec);
    assert_eq!(kind, FrameKind::Logits, "connection must survive a bad frame");
    assert_eq!(WireResponse::decode(&body).unwrap().id, 10);

    // Garbage magic: fatal, the server closes this connection.
    stream.write_all(b"\0\0\0\0garbage-not-a-frame").unwrap();
    let mut buf = [0u8; 256];
    let t0 = Instant::now();
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break, // server hung up, as it must
            Ok(_) => panic!("server answered a corrupt-magic stream"),
            Err(e) => panic!("expected clean close, got {e}"),
        }
    }
    assert!(t0.elapsed() < Duration::from_secs(20));

    // The listener itself is unharmed: a fresh connection serves.
    let mut fresh = connect(&net);
    let mut dec2 = FrameDecoder::new();
    send_request(&mut fresh, 11, "alt", random_codes(&mut rng, 16 * 16, 4));
    let (kind, _) = recv_frame(&mut fresh, &mut dec2);
    assert_eq!(kind, FrameKind::Logits);
    let c = net.shutdown();
    assert!(c.proto_errors >= 2, "both bad frames counted: {c:?}");
}

/// `shutdown` drains gracefully: a request in flight when the stop lands
/// still gets its answer before the listener thread exits.
#[test]
fn shutdown_drains_inflight_requests() {
    let (net, _registry) = serve(8);
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(23);
    send_request(&mut stream, 1, "base", random_codes(&mut rng, 16 * 16, 4));
    // Wait until the request is admitted (a drain that starts first would
    // legitimately nack it), then shut down with the answer in flight.
    let t0 = Instant::now();
    while net.counters().accepted < 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "request never admitted");
        std::thread::sleep(Duration::from_millis(1));
    }
    let handle = std::thread::spawn(move || net.shutdown());
    let (kind, body) = recv_frame(&mut stream, &mut dec);
    assert_eq!(kind, FrameKind::Logits, "drain must answer in-flight work");
    assert_eq!(WireResponse::decode(&body).unwrap().id, 1);
    let c = handle.join().unwrap();
    assert_eq!(c.completed, 1);
}

/// ISSUE-10 tentpole criterion: with 4 loop shards and 8 concurrent
/// clients, every response stays bit-identical to the in-process
/// forward, every id is answered exactly once per connection, and the
/// least-connections acceptor actually spreads the connections over
/// more than one shard.
#[test]
fn four_shards_bit_identical_and_exactly_once_under_concurrency() {
    const CLIENTS: u64 = 8;
    const PER_CLIENT: u64 = 25;
    let (net, registry) = serve_with(NetOpts {
        loops: 4,
        max_inflight: 256,
        ..NetOpts::default()
    });
    let addr = net.addr();
    let clients: Vec<_> = (0..CLIENTS)
        .map(|t| {
            let reg = Arc::clone(&registry);
            std::thread::spawn(move || {
                // Each client compiles its own reference networks; the
                // serving path must agree with them bit for bit from
                // every shard.
                let compile_ref = |name: &str| {
                    let entry = reg.model(name).unwrap();
                    entry
                        .spec
                        .compile_with_defaults(&entry.weights, &Arc::new(TableStore::new()))
                        .unwrap()
                };
                let ref_base = compile_ref("base");
                let ref_alt = compile_ref("alt");
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
                stream.set_nodelay(true).unwrap();
                let mut dec = FrameDecoder::new();
                let mut rng = Rng::new(0xA11 + t);
                let mut seen = vec![false; PER_CLIENT as usize];
                for i in 0..PER_CLIENT {
                    let (model, reference) = if (t + i) % 2 == 0 {
                        ("base", &ref_base)
                    } else {
                        ("alt", &ref_alt)
                    };
                    let codes = random_codes(&mut rng, 16 * 16, 4);
                    let img = Tensor4::from_vec(Shape4::new(1, 16, 16, 1), codes.clone());
                    let expect = reference.forward(&img);
                    send_request(&mut stream, i, model, codes);
                    let (kind, body) = recv_frame(&mut stream, &mut dec);
                    assert_eq!(kind, FrameKind::Logits, "client {t} request {i}");
                    let resp = WireResponse::decode(&body).unwrap();
                    assert!(!seen[resp.id as usize], "client {t}: duplicate id {}", resp.id);
                    seen[resp.id as usize] = true;
                    assert_eq!(resp.id, i, "in-order single-stream round trips echo ids");
                    assert_eq!(
                        resp.logits, expect[0],
                        "client {t} request {i} model {model}: shard-served logits drifted"
                    );
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    let stats = net.shard_stats();
    assert_eq!(stats.len(), 4, "one stat row per loop shard");
    let accepted: u64 = stats.iter().map(|s| s.accepted).sum();
    let completed: u64 = stats.iter().map(|s| s.completed).sum();
    assert_eq!(accepted, CLIENTS, "every connection lands on exactly one shard");
    assert_eq!(completed, CLIENTS * PER_CLIENT, "per-shard goodput sums to the total");
    let busy = stats.iter().filter(|s| s.accepted > 0).count();
    assert!(
        busy >= 2,
        "least-connections must spread {CLIENTS} concurrent conns across shards: {stats:?}"
    );
    let c = net.shutdown();
    assert_eq!(c.completed, CLIENTS * PER_CLIENT);
    assert_eq!(c.shed, 0);
}

/// Per-connection token-bucket rate limiting: a burst far beyond the
/// configured rate gets explicit `Overloaded` nacks that are counted as
/// shed, while at least the bucket's burst capacity is served.
#[test]
fn per_connection_rate_limit_nacks_count_as_shed() {
    const TOTAL: usize = 30;
    // 1 rps => burst capacity 2. Refilling the other 28 tokens would take
    // 28 s, far beyond this test's lifetime, so most of the burst sheds.
    let (net, _registry) = serve_with(NetOpts {
        max_inflight: 64,
        conn_rate_limit: 1,
        ..NetOpts::default()
    });
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(77);
    let mut burst = Vec::new();
    for i in 0..TOTAL {
        let req = WireRequest {
            id: i as u64,
            model: "base".to_string(),
            h: 16,
            w: 16,
            c: 1,
            codes: random_codes(&mut rng, 16 * 16, 4),
        };
        burst.extend_from_slice(&encode_frame(FrameKind::Infer, &req.encode()));
    }
    stream.write_all(&burst).unwrap();

    let mut completed = 0usize;
    let mut shed = 0usize;
    let mut seen = vec![false; TOTAL];
    for _ in 0..TOTAL {
        match recv_frame(&mut stream, &mut dec) {
            (FrameKind::Logits, body) => {
                let resp = WireResponse::decode(&body).unwrap();
                assert!(!seen[resp.id as usize], "duplicate answer for id {}", resp.id);
                seen[resp.id as usize] = true;
                completed += 1;
            }
            (FrameKind::Overloaded, body) => {
                let nack = WireNack::decode(&body).unwrap();
                assert!(!seen[nack.id as usize], "duplicate answer for id {}", nack.id);
                seen[nack.id as usize] = true;
                assert!(
                    nack.message.contains("rate limit"),
                    "nack must name the rate limit, got: {}",
                    nack.message
                );
                shed += 1;
            }
            (kind, _) => panic!("unexpected frame kind {kind:?}"),
        }
    }
    assert_eq!(completed + shed, TOTAL, "every request answered exactly once");
    assert!(completed >= 2, "the bucket starts full: burst capacity must serve");
    assert!(shed >= 10, "a {TOTAL}-deep burst at 1 rps must shed most of itself");
    drop(stream);
    let c = net.shutdown();
    assert_eq!(c.completed as usize, completed);
    assert_eq!(c.shed as usize, shed, "rate-limit nacks must be counted as shed");
}

/// Autoscaler end to end: a 1-worker pool under sustained socket burst
/// scales up toward `[net] max_workers`, every admitted request is still
/// answered exactly once (no in-flight work lost across the resize), and
/// once the line goes quiet the pool parks back down to the floor.
#[test]
fn autoscaler_scales_up_under_burst_then_parks_when_idle() {
    let store = Arc::new(TableStore::new());
    let registry = Arc::new(
        ModelRegistry::start_with_store(
            &[model_cfg("base", 7)],
            &ServerOpts {
                workers: 1,
                max_batch: 4,
                batch_deadline: Duration::from_millis(1),
                queue_capacity: 4096,
            },
            store,
        )
        .unwrap(),
    );
    let net_opts = NetOpts {
        addr: "127.0.0.1:0".to_string(),
        loops: 2,
        max_inflight: 4096,
        slo: Duration::from_millis(25),
        min_workers: 1,
        max_workers: 3,
        ..NetOpts::default()
    };
    let net = NetServer::start(Arc::clone(&registry), &net_opts).unwrap();
    let pool = Arc::clone(registry.pools()[0].1);
    assert_eq!(pool.worker_count(), 1, "the pool starts at its configured size");

    // Writer half: blast requests on a cloned stream handle until the
    // scaler is seen reacting; reader half (this thread) drains answers.
    let mut stream = connect(&net);
    let wstream = stream.try_clone().unwrap();
    let stop = Arc::new(AtomicBool::new(false));
    let writer_stop = Arc::clone(&stop);
    let writer = std::thread::spawn(move || {
        let mut wstream = wstream;
        let mut rng = Rng::new(31);
        let mut id = 0u64;
        while !writer_stop.load(Ordering::SeqCst) {
            send_request(&mut wstream, id, "base", random_codes(&mut rng, 16 * 16, 4));
            id += 1;
        }
        id
    });

    let mut dec = FrameDecoder::new();
    let mut answered = std::collections::HashSet::new();
    let mut completed = 0u64;
    let mut shed = 0u64;
    let mut peak_workers = 1usize;
    let t0 = Instant::now();
    let drain_answers = |stream: &mut TcpStream,
                         dec: &mut FrameDecoder,
                         answered: &mut std::collections::HashSet<u64>,
                         completed: &mut u64,
                         shed: &mut u64| {
        let (kind, body) = recv_frame(stream, dec);
        let id = match kind {
            FrameKind::Logits => {
                *completed += 1;
                WireResponse::decode(&body).unwrap().id
            }
            FrameKind::Overloaded => {
                *shed += 1;
                WireNack::decode(&body).unwrap().id
            }
            other => panic!("unexpected frame kind {other:?}"),
        };
        assert!(answered.insert(id), "id {id} answered twice");
    };
    // Phase 1: sustain pressure until the scaler grows the pool.
    while peak_workers < 2 {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "scaler never scaled up under sustained burst (workers={peak_workers})"
        );
        drain_answers(&mut stream, &mut dec, &mut answered, &mut completed, &mut shed);
        peak_workers = peak_workers.max(pool.worker_count());
    }
    stop.store(true, Ordering::SeqCst);
    let sent = writer.join().unwrap();
    // Phase 2: drain every remaining answer — nothing admitted may be lost
    // across the resize.
    while (answered.len() as u64) < sent {
        drain_answers(&mut stream, &mut dec, &mut answered, &mut completed, &mut shed);
    }
    assert_eq!(completed + shed, sent, "every request answered exactly once");
    assert!(completed > 0, "the pool must have served under burst");
    assert!(peak_workers >= 2, "burst must grow the pool above the floor");
    assert!(
        peak_workers <= 3,
        "the scaler must respect [net] max_workers, saw {peak_workers}"
    );

    // Phase 3: the line is quiet; the pool parks back down to min_workers.
    let t1 = Instant::now();
    while pool.worker_count() > 1 {
        assert!(
            t1.elapsed() < Duration::from_secs(20),
            "idle pool never parked back to the floor (workers={})",
            pool.worker_count()
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(pool.target_workers(), 1, "scaler target must settle at the floor");
    drop(stream);
    let c = net.shutdown();
    assert_eq!(c.completed, completed);
    assert_eq!(c.shed, shed);
}
