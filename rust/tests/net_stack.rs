//! Socket serving tier end-to-end (ISSUE-9 acceptance): responses served
//! over a real loopback TCP connection must be bit-identical to an
//! in-process `CompiledNetwork::forward` on the same inputs; overload
//! must be signaled with explicit `Overloaded` frames while the
//! dispatcher's in-flight budget stays bounded; the HTTP adapter must
//! answer `/healthz` and `/metrics` on the same port; and a corrupted
//! frame must be survivable — nacked without killing the connection.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use pcilt::config::{EngineKind, ModelConfig};
use pcilt::coordinator::{ModelRegistry, ServerOpts};
use pcilt::net::proto::{encode_frame, FrameDecoder, FrameKind, WireNack, WireRequest, WireResponse};
use pcilt::net::{NetOpts, NetServer};
use pcilt::pcilt::TableStore;
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;

fn model_cfg(name: &str, seed: u64) -> ModelConfig {
    ModelConfig {
        name: name.to_string(),
        engine: EngineKind::Pcilt,
        act_bits: 4,
        seed,
        ..ModelConfig::default()
    }
}

fn opts() -> ServerOpts {
    ServerOpts {
        workers: 2,
        max_batch: 4,
        batch_deadline: Duration::from_millis(1),
        queue_capacity: 64,
    }
}

/// Boot a two-model registry plus socket tier on an ephemeral port.
fn serve(max_inflight: usize) -> (NetServer, Arc<ModelRegistry>) {
    let store = Arc::new(TableStore::new());
    let registry = Arc::new(
        ModelRegistry::start_with_store(
            &[model_cfg("base", 7), model_cfg("alt", 21)],
            &opts(),
            store,
        )
        .unwrap(),
    );
    let net_opts = NetOpts {
        addr: "127.0.0.1:0".to_string(),
        max_inflight,
        ..NetOpts::default()
    };
    let net = NetServer::start(Arc::clone(&registry), &net_opts).unwrap();
    (net, registry)
}

fn connect(net: &NetServer) -> TcpStream {
    let stream = TcpStream::connect(net.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
}

fn random_codes(rng: &mut Rng, len: usize, act_bits: u32) -> Vec<u8> {
    let mask = ((1u32 << act_bits) - 1) as u8;
    (0..len).map(|_| (rng.next_u32() as u8) & mask).collect()
}

fn send_request(stream: &mut TcpStream, id: u64, model: &str, codes: Vec<u8>) {
    let req = WireRequest {
        id,
        model: model.to_string(),
        h: 16,
        w: 16,
        c: 1,
        codes,
    };
    stream.write_all(&encode_frame(FrameKind::Infer, &req.encode())).unwrap();
}

/// Blocking-read until the decoder yields one frame.
fn recv_frame(stream: &mut TcpStream, dec: &mut FrameDecoder) -> (FrameKind, Vec<u8>) {
    loop {
        if let Some(frame) = dec.next_frame().expect("protocol error from server") {
            return frame;
        }
        let mut buf = [0u8; 4096];
        let n = stream.read(&mut buf).expect("read from server");
        assert!(n > 0, "server closed the connection unexpectedly");
        dec.extend(&buf[..n]);
    }
}

/// The tentpole bit-identity criterion: for both models, logits served
/// over the socket equal `CompiledNetwork::forward` on the same codes,
/// request for request.
#[test]
fn socket_responses_bit_identical_to_in_process_forward() {
    let (net, registry) = serve(16);
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(404);
    for (i, model) in ["base", "alt", "base", "alt", "base", "alt"].iter().enumerate() {
        let entry = registry.model(model).unwrap();
        let standalone = entry
            .spec
            .compile_with_defaults(&entry.weights, &Arc::new(TableStore::new()))
            .unwrap();
        let codes = random_codes(&mut rng, 16 * 16, 4);
        let img = Tensor4::from_vec(Shape4::new(1, 16, 16, 1), codes.clone());
        let expect = standalone.forward(&img);

        send_request(&mut stream, i as u64, model, codes);
        let (kind, body) = recv_frame(&mut stream, &mut dec);
        assert_eq!(kind, FrameKind::Logits, "request {i}");
        let resp = WireResponse::decode(&body).unwrap();
        assert_eq!(resp.id, i as u64, "response must echo the wire id");
        assert_eq!(resp.model, *model);
        assert_eq!(
            resp.logits, expect[0],
            "model {model} request {i}: socket-served logits != in-process forward"
        );
        // Same argmax (incl. tie-breaking) as the serving worker.
        let argmax = expect[0]
            .iter()
            .enumerate()
            .max_by_key(|(_, &v)| v)
            .map(|(idx, _)| idx)
            .unwrap();
        assert_eq!(resp.class as usize, argmax);
        assert!(resp.batch_size >= 1);
    }
    drop(stream);
    let c = net.shutdown();
    assert_eq!(c.accepted, 6);
    assert_eq!(c.completed, 6);
    assert_eq!(c.shed, 0);
}

/// Overload: blast one connection with far more requests than the
/// in-flight budget admits. Every request must be answered explicitly
/// (Logits or Overloaded — never silence), the dispatcher's observable
/// in-flight count must never exceed the budget, and the budget must
/// fully release afterwards.
#[test]
fn overload_sheds_explicitly_with_bounded_inflight() {
    const BUDGET: usize = 2;
    const TOTAL: usize = 64;
    let (net, _registry) = serve(BUDGET);
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(99);
    // Send the whole burst before reading anything: admission control has
    // to decide under pressure, not one request at a time.
    let mut burst = Vec::new();
    for i in 0..TOTAL {
        let req = WireRequest {
            id: i as u64,
            model: "base".to_string(),
            h: 16,
            w: 16,
            c: 1,
            codes: random_codes(&mut rng, 16 * 16, 4),
        };
        burst.extend_from_slice(&encode_frame(FrameKind::Infer, &req.encode()));
    }
    stream.write_all(&burst).unwrap();

    let mut completed = 0usize;
    let mut shed = 0usize;
    let mut seen = vec![false; TOTAL];
    for _ in 0..TOTAL {
        // The budget is observable mid-flight and must stay bounded.
        assert!(
            net.dispatcher().inflight("base") <= BUDGET,
            "in-flight exceeded the admission budget"
        );
        match recv_frame(&mut stream, &mut dec) {
            (FrameKind::Logits, body) => {
                let resp = WireResponse::decode(&body).unwrap();
                assert!(!seen[resp.id as usize], "duplicate answer for id {}", resp.id);
                seen[resp.id as usize] = true;
                completed += 1;
            }
            (FrameKind::Overloaded, body) => {
                let nack = WireNack::decode(&body).unwrap();
                assert!(!seen[nack.id as usize], "duplicate answer for id {}", nack.id);
                seen[nack.id as usize] = true;
                assert!(nack.message.contains("budget") || nack.message.contains("bound"));
                shed += 1;
            }
            (kind, _) => panic!("unexpected frame kind {kind:?}"),
        }
    }
    assert_eq!(completed + shed, TOTAL, "every request answered exactly once");
    assert!(completed >= BUDGET, "the admitted prefix must complete");
    assert!(shed > 0, "a {TOTAL}-deep burst over budget {BUDGET} must shed");
    // Budget fully released once everything is answered.
    let t0 = Instant::now();
    while net.dispatcher().inflight("base") != 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "in-flight budget leaked");
        std::thread::sleep(Duration::from_millis(1));
    }
    let c = net.shutdown();
    assert_eq!(c.completed as usize, completed);
    assert_eq!(c.shed as usize, shed);
}

/// The HTTP adapter shares the binary port: `/healthz` answers 200 ok,
/// `/metrics` renders the net counters and per-model series, and unknown
/// paths get a 404 — each on a connection that then closes.
#[test]
fn healthz_and_metrics_served_on_the_same_port() {
    let (net, _registry) = serve(8);
    // Prime one completed request so the metrics move off zero.
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(5);
    send_request(&mut stream, 1, "", random_codes(&mut rng, 16 * 16, 4));
    let (kind, _) = recv_frame(&mut stream, &mut dec);
    assert_eq!(kind, FrameKind::Logits);
    drop(stream);

    let http = |request: &str| -> String {
        let mut s = connect(&net);
        s.write_all(request.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap(); // server closes after answering
        out
    };
    let health = http("GET /healthz HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(health.starts_with("HTTP/1.1 200"), "{health}");
    assert!(health.ends_with("ok\n"), "{health}");

    let metrics = http("GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(metrics.starts_with("HTTP/1.1 200"), "{metrics}");
    for needle in [
        "pcilt_net_accepted 1",
        "pcilt_net_completed 1",
        "pcilt_model_completed{model=\"base\"}",
        "pcilt_model_p999_ns{model=\"alt\"}",
        "pcilt_model_queue_depth{model=\"base\"}",
    ] {
        assert!(metrics.contains(needle), "missing {needle} in:\n{metrics}");
    }

    let missing = http("GET /nope HTTP/1.1\r\nHost: t\r\n\r\n");
    assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");
    net.shutdown();
}

/// A corrupted frame (bad checksum) is nacked and consumed; the same
/// connection then serves a valid request. A broken magic, by contrast,
/// is fatal: the server closes that connection — but keeps serving new
/// ones.
#[test]
fn connection_survives_bad_frame_but_not_bad_magic() {
    let (net, _registry) = serve(8);
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(17);

    // Corrupt the checksum trailer of an otherwise valid frame.
    let req = WireRequest {
        id: 9,
        model: "base".to_string(),
        h: 16,
        w: 16,
        c: 1,
        codes: random_codes(&mut rng, 16 * 16, 4),
    };
    let mut bad = encode_frame(FrameKind::Infer, &req.encode());
    let last = bad.len() - 1;
    bad[last] ^= 0xFF;
    stream.write_all(&bad).unwrap();
    let (kind, body) = recv_frame(&mut stream, &mut dec);
    assert_eq!(kind, FrameKind::Error, "checksum mismatch must be nacked");
    let nack = WireNack::decode(&body).unwrap();
    assert!(nack.message.contains("checksum"), "{}", nack.message);

    // Same connection, valid frame: still served.
    send_request(&mut stream, 10, "base", random_codes(&mut rng, 16 * 16, 4));
    let (kind, body) = recv_frame(&mut stream, &mut dec);
    assert_eq!(kind, FrameKind::Logits, "connection must survive a bad frame");
    assert_eq!(WireResponse::decode(&body).unwrap().id, 10);

    // Garbage magic: fatal, the server closes this connection.
    stream.write_all(b"\0\0\0\0garbage-not-a-frame").unwrap();
    let mut buf = [0u8; 256];
    let t0 = Instant::now();
    loop {
        match stream.read(&mut buf) {
            Ok(0) => break, // server hung up, as it must
            Ok(_) => panic!("server answered a corrupt-magic stream"),
            Err(e) => panic!("expected clean close, got {e}"),
        }
    }
    assert!(t0.elapsed() < Duration::from_secs(20));

    // The listener itself is unharmed: a fresh connection serves.
    let mut fresh = connect(&net);
    let mut dec2 = FrameDecoder::new();
    send_request(&mut fresh, 11, "alt", random_codes(&mut rng, 16 * 16, 4));
    let (kind, _) = recv_frame(&mut fresh, &mut dec2);
    assert_eq!(kind, FrameKind::Logits);
    let c = net.shutdown();
    assert!(c.proto_errors >= 2, "both bad frames counted: {c:?}");
}

/// `shutdown` drains gracefully: a request in flight when the stop lands
/// still gets its answer before the listener thread exits.
#[test]
fn shutdown_drains_inflight_requests() {
    let (net, _registry) = serve(8);
    let mut stream = connect(&net);
    let mut dec = FrameDecoder::new();
    let mut rng = Rng::new(23);
    send_request(&mut stream, 1, "base", random_codes(&mut rng, 16 * 16, 4));
    // Wait until the request is admitted (a drain that starts first would
    // legitimately nack it), then shut down with the answer in flight.
    let t0 = Instant::now();
    while net.counters().accepted < 1 {
        assert!(t0.elapsed() < Duration::from_secs(10), "request never admitted");
        std::thread::sleep(Duration::from_millis(1));
    }
    let handle = std::thread::spawn(move || net.shutdown());
    let (kind, body) = recv_frame(&mut stream, &mut dec);
    assert_eq!(kind, FrameKind::Logits, "drain must answer in-flight work");
    assert_eq!(WireResponse::decode(&body).unwrap().id, 1);
    let c = handle.join().unwrap();
    assert_eq!(c.completed, 1);
}
