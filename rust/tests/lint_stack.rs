//! `pcilt lint` stack tests: one fixture per rule asserting the diagnostic
//! lands at the right `file:line`, pragma suppression, the lock-rank
//! simulation (in-file and cross-module via `acquires`), a full self-scan
//! of the crate sources (must be clean — this is the CI gate), and the
//! `pcilt lint` CLI exit codes and `--json` output.

use std::path::PathBuf;
use std::process::Command;

use pcilt::analysis::{lint_files, lint_root, FileData, Report};

/// Build a `FileData` at a policy-relevant relative path. Fixture sources
/// are written with a leading newline for readability; strip it so the
/// first fixture line is line 1.
fn fd(rel: &str, src: &str) -> FileData {
    let src = src.strip_prefix('\n').unwrap_or(src);
    FileData::new(rel.to_string(), src.to_string())
}

fn lint_one(rel: &str, src: &str) -> Report {
    lint_files(vec![fd(rel, src)])
}

fn has(r: &Report, file: &str, line: u32, rule: &str) -> bool {
    r.diagnostics
        .iter()
        .any(|d| d.file == file && d.line == line && d.rule == rule)
}

fn rules_hit(r: &Report) -> Vec<&'static str> {
    let mut v: Vec<&'static str> = r.diagnostics.iter().map(|d| d.rule).collect();
    v.sort_unstable();
    v.dedup();
    v
}

// ---------------------------------------------------------------------------
// float-free
// ---------------------------------------------------------------------------

#[test]
fn float_free_flags_floats_at_line() {
    let r = lint_one(
        "pcilt/tile.rs",
        r#"
pub fn walk(x: u32) -> u32 {
    let bad = x as f32;
    let also = 0.5f64;
    bad as u32 + also as u32
}
"#,
    );
    assert!(has(&r, "pcilt/tile.rs", 2, "float-free"), "{r:?}");
    assert!(has(&r, "pcilt/tile.rs", 3, "float-free"), "{r:?}");
    assert_eq!(rules_hit(&r), vec!["float-free"]);
}

#[test]
fn float_free_scoped_to_policy_files_and_non_test_code() {
    // Same source outside the code-domain module list: clean.
    let src = "pub fn f(x: f64) -> f64 {\n    x\n}\n";
    assert!(lint_one("util/logger.rs", src).is_clean());
    // Floats inside #[cfg(test)] are exempt even in policy files.
    let r = lint_one(
        "pcilt/tile.rs",
        r#"
pub fn walk(x: u32) -> u32 {
    x
}
#[cfg(test)]
mod tests {
    #[test]
    fn approx() {
        let _tol = 1.0f64;
    }
}
"#,
    );
    assert!(r.is_clean(), "{r:?}");
}

#[test]
fn float_in_comment_or_string_is_not_a_token() {
    let r = lint_one(
        "pcilt/tile.rs",
        r#"
// mentions f32 and f64 in prose
pub fn walk() -> &'static str {
    "f32 f64 1.5f32"
}
"#,
    );
    assert!(r.is_clean(), "{r:?}");
}

// ---------------------------------------------------------------------------
// pragmas
// ---------------------------------------------------------------------------

#[test]
fn trailing_pragma_suppresses_named_rule_only() {
    // allow(float-free) on the line: suppressed.
    let ok = lint_one(
        "pcilt/tile.rs",
        "pub fn f(x: u32) -> u32 {\n    \
         (x as f32) as u32 // pcilt-lint: allow(float-free)\n}\n",
    );
    assert!(ok.is_clean(), "{ok:?}");
    // A pragma naming a different rule does not suppress.
    let bad = lint_one(
        "pcilt/tile.rs",
        "pub fn f(x: u32) -> u32 {\n    \
         (x as f32) as u32 // pcilt-lint: allow(no-panic)\n}\n",
    );
    assert!(has(&bad, "pcilt/tile.rs", 2, "float-free"), "{bad:?}");
}

#[test]
fn own_line_pragma_covers_next_item() {
    let r = lint_one(
        "pcilt/tile.rs",
        r#"
// pcilt-lint: allow(float-free)
pub fn estimate(x: u32) -> f64 {
    x as f64 * 1.5
}
pub fn walk(x: u32) -> u32 {
    x as f32 as u32
}
"#,
    );
    // The fn under the pragma is exempt; the next fn is not.
    assert!(!has(&r, "pcilt/tile.rs", 2, "float-free"), "{r:?}");
    assert!(!has(&r, "pcilt/tile.rs", 3, "float-free"), "{r:?}");
    assert!(has(&r, "pcilt/tile.rs", 6, "float-free"), "{r:?}");
}

#[test]
fn doc_comment_pragma_is_inert() {
    // Pragmas are only active in plain `//` comments; doc comments may
    // quote the syntax without suppressing anything.
    let r = lint_one(
        "pcilt/tile.rs",
        r#"
/// pcilt-lint: allow(float-free)
pub fn walk(x: u32) -> u32 {
    x as f32 as u32
}
"#,
    );
    assert!(has(&r, "pcilt/tile.rs", 3, "float-free"), "{r:?}");
}

// ---------------------------------------------------------------------------
// det-persist
// ---------------------------------------------------------------------------

#[test]
fn det_persist_flags_nondeterminism_in_serde_fns() {
    let r = lint_one(
        "pcilt/store.rs",
        r#"
use std::collections::HashMap;
pub fn write_to(out: &mut Vec<u8>) {
    let m: HashMap<u32, u32> = HashMap::new();
    out.push(m.len() as u8);
}
pub fn unrelated() -> usize {
    let m: HashMap<u32, u32> = HashMap::new();
    m.len()
}
"#,
    );
    // Banned ident inside a persistence fn: flagged at its line.
    assert!(has(&r, "pcilt/store.rs", 3, "det-persist"), "{r:?}");
    // The same ident outside the persistence surface is fine.
    assert!(!has(&r, "pcilt/store.rs", 7, "det-persist"), "{r:?}");
}

// ---------------------------------------------------------------------------
// no-panic
// ---------------------------------------------------------------------------

#[test]
fn no_panic_flags_unwrap_but_allows_lock_poison_idiom() {
    let r = lint_one(
        "coordinator/server.rs",
        r#"
pub fn go(x: Option<u32>) -> u32 {
    x.unwrap()
}
pub fn ok(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
"#,
    );
    assert!(has(&r, "coordinator/server.rs", 2, "no-panic"), "{r:?}");
    let n = r.diagnostics.iter().filter(|d| d.rule == "no-panic").count();
    assert_eq!(n, 1, "poison idiom and test code must not count: {r:?}");
}

// ---------------------------------------------------------------------------
// registry
// ---------------------------------------------------------------------------

#[test]
fn registry_requires_info_on_engine_impls() {
    let bad = lint_one(
        "pcilt/custom.rs",
        r#"
impl ConvEngine for Custom {
    fn name(&self) -> &'static str {
        "custom"
    }
}
"#,
    );
    assert!(has(&bad, "pcilt/custom.rs", 1, "registry"), "{bad:?}");
    let ok = lint_one(
        "pcilt/custom.rs",
        r#"
impl ConvEngine for Custom {
    fn name(&self) -> &'static str {
        "custom"
    }
    fn info(&self) -> EngineInfo {
        EngineInfo { name: "custom", exact: true, table_bytes: 0 }
    }
}
"#,
    );
    assert!(ok.is_clean(), "{ok:?}");
}

#[test]
fn registry_requires_band_and_store_surface_per_policy() {
    // pcilt/lookup.rs is on both the conv_rows and from_store lists.
    let r = lint_one(
        "pcilt/lookup.rs",
        r#"
impl ConvEngine for PciltEngine {
    fn info(&self) -> EngineInfo {
        EngineInfo { name: "pcilt", exact: true, table_bytes: 0 }
    }
}
"#,
    );
    assert!(has(&r, "pcilt/lookup.rs", 1, "registry"), "{r:?}");
    let msg = &r.diagnostics.iter().find(|d| d.rule == "registry").unwrap().message;
    assert!(msg.contains("conv_rows") && msg.contains("from_store"), "{msg}");
}

#[test]
fn registry_kind_tags_need_both_match_arms() {
    let r = lint_one(
        "pcilt/store.rs",
        r#"
pub const KIND_A: u8 = 1;
pub const KIND_B: u8 = 2;
pub enum TableArtifact {
    A(Vec<u8>),
    B { x: u32 },
}
pub fn write_kind(a: bool) -> u8 {
    match a {
        true => KIND_A,
        false => KIND_B,
    }
}
pub fn read_kind(k: u8) -> u32 {
    match k {
        KIND_A => 1,
        _ => 0,
    }
}
"#,
    );
    // KIND_B is written but never read back: flagged at its declaration.
    assert!(has(&r, "pcilt/store.rs", 2, "registry"), "{r:?}");
    assert!(!has(&r, "pcilt/store.rs", 1, "registry"), "{r:?}");
}

#[test]
fn registry_artifact_variants_match_kind_count() {
    let r = lint_one(
        "pcilt/store.rs",
        r#"
pub const KIND_A: u8 = 1;
pub enum TableArtifact {
    A(Vec<u8>),
    B { x: u32 },
}
pub fn roundtrip(a: bool, k: u8) -> u8 {
    let w = match a {
        true => KIND_A,
        false => 0,
    };
    match k {
        KIND_A => w,
        _ => 0,
    }
}
"#,
    );
    // 2 variants vs 1 KIND constant: flagged at the enum.
    assert!(has(&r, "pcilt/store.rs", 2, "registry"), "{r:?}");
}

// ---------------------------------------------------------------------------
// line-width / brace-balance
// ---------------------------------------------------------------------------

#[test]
fn line_width_flags_overlong_lines() {
    let long = format!("// {}\n", "x".repeat(120));
    let r = lint_one("util/other.rs", &long);
    assert!(has(&r, "util/other.rs", 1, "line-width"), "{r:?}");
}

#[test]
fn brace_balance_flags_stray_close() {
    let r = lint_one("util/other.rs", "pub fn f() {}\n}\n");
    assert!(has(&r, "util/other.rs", 2, "brace-balance"), "{r:?}");
}

// ---------------------------------------------------------------------------
// lock-order
// ---------------------------------------------------------------------------

const LOCK_FIXTURE: &str = r#"
use std::sync::Mutex;
pub struct S {
    // pcilt-lint: lock-rank(alpha = 10)
    a: Mutex<u32>,
    // pcilt-lint: lock-rank(beta = 20)
    b: Mutex<u32>,
}
impl S {
    pub fn bad(&self) -> u32 {
        let gb = self.b.lock().unwrap();
        let ga = self.a.lock().unwrap();
        *ga + *gb
    }
    pub fn good(&self) -> u32 {
        let ga = self.a.lock().unwrap();
        let gb = self.b.lock().unwrap();
        *ga + *gb
    }
}
"#;

#[test]
fn lock_order_flags_rank_inversion_at_line() {
    let r = lint_one("coordinator/sim.rs", LOCK_FIXTURE);
    // bad(): alpha (10) acquired while beta (20) held -> line 11.
    assert!(has(&r, "coordinator/sim.rs", 11, "lock-order"), "{r:?}");
    // good(): increasing ranks, no diagnostic on line 16.
    assert!(!has(&r, "coordinator/sim.rs", 16, "lock-order"), "{r:?}");
    let n = r.diagnostics.iter().filter(|d| d.rule == "lock-order").count();
    assert_eq!(n, 1, "{r:?}");
}

#[test]
fn lock_order_pragma_suppresses() {
    let src = LOCK_FIXTURE.replace(
        "let ga = self.a.lock().unwrap();\n        *ga + *gb",
        "let ga = self.a.lock().unwrap(); // pcilt-lint: allow(lock-order)\n        *ga + *gb",
    );
    let r = lint_one("coordinator/sim.rs", &src);
    assert!(
        !r.diagnostics.iter().any(|d| d.rule == "lock-order"),
        "{r:?}"
    );
}

#[test]
fn lock_order_tracks_cross_module_acquires() {
    let store = r#"
use std::sync::Mutex;
pub struct T {
    // pcilt-lint: lock-rank(store = 30)
    inner: Mutex<u32>,
}
impl T {
    // pcilt-lint: acquires(store)
    pub fn stats(&self) -> u32 {
        *self.inner.lock().unwrap()
    }
}
"#;
    let metrics_bad = r#"
use std::sync::Mutex;
pub struct M {
    // pcilt-lint: lock-rank(metrics = 40)
    inner: Mutex<u32>,
}
impl M {
    pub fn snap(&self, t: &T) -> u32 {
        let g = self.inner.lock().unwrap();
        *g + t.stats()
    }
}
"#;
    // metrics outranks store: calling into the store while holding it is
    // an inversion, reported at the call site.
    let r = lint_files(vec![
        fd("pcilt/store.rs", store),
        fd("coordinator/metrics.rs", metrics_bad),
    ]);
    assert!(has(&r, "coordinator/metrics.rs", 9, "lock-order"), "{r:?}");
    // With metrics below store (the repo's actual ranking) it is legal.
    let metrics_ok = metrics_bad.replace("metrics = 40", "metrics = 20");
    let r = lint_files(vec![
        fd("pcilt/store.rs", store),
        fd("coordinator/metrics.rs", &metrics_ok),
    ]);
    assert!(
        !r.diagnostics.iter().any(|d| d.rule == "lock-order"),
        "{r:?}"
    );
}

// ---------------------------------------------------------------------------
// self-scan + CLI
// ---------------------------------------------------------------------------

fn crate_src() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("src")
}

#[test]
fn self_scan_is_clean() {
    let r = lint_root(&crate_src()).expect("scan crate sources");
    assert!(r.files >= 60, "suspiciously few files: {}", r.files);
    assert!(
        r.is_clean(),
        "crate sources must lint clean:\n{}",
        r.text()
    );
}

#[test]
fn cli_lint_exits_zero_on_clean_tree() {
    let out = Command::new(env!("CARGO_BIN_EXE_pcilt"))
        .args(["lint", "--root"])
        .arg(crate_src())
        .output()
        .expect("run pcilt lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "stdout: {stdout}");
    assert!(stdout.contains("0 violation(s)"), "{stdout}");
}

#[test]
fn cli_lint_exits_nonzero_with_json_on_violations() {
    let dir = std::env::temp_dir().join("pcilt_lint_stack_fixture");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("pcilt")).expect("mkdir");
    std::fs::write(
        dir.join("pcilt/tile.rs"),
        "pub fn walk(x: u32) -> u32 {\n    x as f32 as u32\n}\n",
    )
    .expect("write fixture");

    let out = Command::new(env!("CARGO_BIN_EXE_pcilt"))
        .args(["lint", "--root"])
        .arg(&dir)
        .output()
        .expect("run pcilt lint");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(!out.status.success(), "must fail: {stdout}");
    assert!(stdout.contains("pcilt/tile.rs:2: float-free"), "{stdout}");

    let out = Command::new(env!("CARGO_BIN_EXE_pcilt"))
        .args(["lint", "--json", "--root"])
        .arg(&dir)
        .output()
        .expect("run pcilt lint --json");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(!out.status.success(), "must fail: {stdout}");
    assert!(stdout.trim_start().starts_with('{'), "{stdout}");
    assert!(stdout.contains("\"rule\":\"float-free\""), "{stdout}");
    assert!(stdout.contains("\"line\":2"), "{stdout}");

    let _ = std::fs::remove_dir_all(&dir);
}
