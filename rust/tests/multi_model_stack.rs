//! Multi-model serving stack: the ISSUE-3 acceptance criteria.
//!
//! Two models sharing identical conv layers (shared backbone, fine-tuned
//! head) must hold exactly ONE table copy in the shared store
//! (`cross_model_dedup >= 1`, store bytes < 2x a single model), per-model
//! routed outputs must be bit-identical to running each model standalone,
//! and an unknown model name must be rejected with a clean error rather
//! than a panic.

use std::sync::Arc;
use std::time::Duration;

use pcilt::config::{Document, EngineKind, ModelConfig, ServeConfig};
use pcilt::coordinator::{ModelRegistry, RegistryError, ServerOpts};
use pcilt::model::{random_params_seeded, randomize_head, EngineChoice, QuantCnn};
use pcilt::pcilt::TableStore;
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;

fn opts() -> ServerOpts {
    ServerOpts {
        workers: 2,
        max_batch: 4,
        batch_deadline: Duration::from_millis(1),
        queue_capacity: 128,
    }
}

fn model_cfg(name: &str, seed: u64, head_seed: Option<u64>) -> ModelConfig {
    ModelConfig {
        name: name.to_string(),
        engine: EngineKind::Pcilt,
        act_bits: 4,
        seed,
        head_seed,
        ..ModelConfig::default()
    }
}

fn image(seed: u64) -> Tensor4<u8> {
    let mut rng = Rng::new(seed);
    Tensor4::random_activations(Shape4::new(1, 16, 16, 1), 4, &mut rng)
}

/// Shared backbone + fine-tuned head => exactly one table copy between the
/// two models, counted by `cross_model_dedup`.
#[test]
fn shared_backbone_holds_one_table_copy() {
    // Baseline: what ONE model costs in a private store.
    let solo_store = Arc::new(TableStore::new());
    let solo_model =
        QuantCnn::with_store(random_params_seeded(4, 7), EngineChoice::Pcilt, &solo_store);
    // materialize the same derived views serving builds (the mirror)
    let _ = solo_model.forward(&image(0));
    let solo = solo_store.stats();
    assert_eq!(
        solo.entries, 4,
        "one model: two conv layers -> dense + absorbed-requant tables"
    );

    let store = Arc::new(TableStore::new());
    let registry = ModelRegistry::start_with_store(
        &[
            model_cfg("base", 7, None),
            model_cfg("tuned", 7, Some(99)), // same backbone, different head
        ],
        &opts(),
        store.clone(),
    )
    .unwrap();
    // exercise both models so every lazily-derived view is built
    for name in ["base", "tuned"] {
        let (_, rx) = registry.route(Some(name), None, image(1)).unwrap();
        rx.recv().unwrap();
    }
    let s = store.stats();
    assert_eq!(
        s.entries, solo.entries,
        "two models sharing a backbone must hold exactly one table copy"
    );
    assert!(
        s.cross_model_dedup >= 1,
        "cross_model_dedup must record the sharing: {s:?}"
    );
    assert_eq!(
        registry.cross_model_dedup(),
        4,
        "both conv-layer keys and both requant keys shared"
    );
    assert!(
        s.bytes < 2.0 * solo.bytes,
        "fleet bytes {} must be < 2x single-model bytes {}",
        s.bytes,
        solo.bytes
    );
    // the per-pool metrics report carries the shared-store counters
    let reports = registry.shutdown();
    assert_eq!(reports.len(), 2);
    for (_, m) in &reports {
        assert!(m.tables.cross_model_dedup >= 1);
    }
}

/// Independent models (different seeds) share nothing — the counter stays
/// at zero and the store holds both table sets.
#[test]
fn independent_models_share_nothing() {
    let store = Arc::new(TableStore::new());
    let registry = ModelRegistry::start_with_store(
        &[model_cfg("m1", 21, None), model_cfg("m2", 22, None)],
        &opts(),
        store.clone(),
    )
    .unwrap();
    let s = store.stats();
    assert_eq!(
        s.entries, 8,
        "two independent models: four distinct conv tables + four requant tables"
    );
    assert_eq!(s.cross_model_dedup, 0);
    assert_eq!(registry.cross_model_dedup(), 0);
}

/// Per-model routed outputs are bit-identical to running each model
/// standalone — borrowing tables from a fleet-shared store changes memory
/// topology, never answers.
#[test]
fn routed_outputs_bit_identical_to_standalone() {
    let store = Arc::new(TableStore::new());
    let registry = ModelRegistry::start_with_store(
        &[model_cfg("base", 7, None), model_cfg("tuned", 7, Some(99))],
        &opts(),
        store,
    )
    .unwrap();
    let mut base_logits = Vec::new();
    let mut tuned_logits = Vec::new();
    for name in ["base", "tuned"] {
        // standalone reference: same spec + weights, private store, no
        // serving
        let entry = registry.model(name).unwrap();
        let standalone = entry
            .spec
            .compile_with_defaults(&entry.weights, &Arc::new(TableStore::new()))
            .unwrap();
        for i in 0..6 {
            let img = image(100 + i);
            let (_, rx) = registry.route(Some(name), None, img.clone()).unwrap();
            let resp = rx.recv().unwrap();
            assert_eq!(resp.model, name);
            let expect = standalone.forward(&img);
            assert_eq!(
                resp.logits, expect[0],
                "model {name} request {i}: served != standalone"
            );
            if name == "base" {
                base_logits.push(resp.logits);
            } else {
                tuned_logits.push(resp.logits);
            }
        }
    }
    // the fine-tuned head actually distinguishes the models
    assert_ne!(
        base_logits, tuned_logits,
        "base and tuned heads must produce different logits"
    );
}

/// Unknown model names are a clean, listing error — not a panic.
#[test]
fn unknown_model_rejected_with_clean_error() {
    let store = Arc::new(TableStore::new());
    let registry =
        ModelRegistry::start_with_store(&[model_cfg("only", 3, None)], &opts(), store).unwrap();
    let err = registry.route(Some("nope"), None, image(2)).unwrap_err();
    assert!(matches!(err, RegistryError::UnknownModel { .. }));
    let msg = err.to_string();
    assert!(msg.contains("'nope'"), "{msg}");
    assert!(msg.contains("only"), "error must list known models: {msg}");
}

/// The `[[models]]` TOML list drives the registry end-to-end: parse a
/// config, start the fleet, serve from both pools.
#[test]
fn models_toml_to_running_fleet() {
    let doc = Document::parse(
        r#"
[serve]
workers = 1
max_batch = 4
[[models]]
name = "base"
engine = "pcilt"
act_bits = 4
seed = 7
[[models]]
name = "tuned"
engine = "pcilt"
act_bits = 4
seed = 7
head_seed = 5
"#,
    )
    .unwrap();
    let cfg = ServeConfig::from_document(&doc).unwrap();
    assert_eq!(cfg.models.len(), 2);
    let store = Arc::new(TableStore::new());
    let registry = ModelRegistry::start_with_store(&cfg.models, &opts(), store.clone()).unwrap();
    assert_eq!(registry.models(), vec!["base", "tuned"]);
    let (_, rx) = registry.route(Some("tuned"), None, image(8)).unwrap();
    assert_eq!(rx.recv().unwrap().model, "tuned");
    // default model (first configured) serves model-less requests
    let (_, rx) = registry.route(None, None, image(9)).unwrap();
    assert_eq!(rx.recv().unwrap().model, "base");
    assert!(store.stats().cross_model_dedup >= 1);
}

/// Sanity for the fine-tuned-head construction the scenarios rely on:
/// conv weights identical, head different.
#[test]
fn head_seed_changes_only_the_head() {
    let base = random_params_seeded(4, 7);
    let mut tuned = random_params_seeded(4, 7);
    randomize_head(&mut tuned, 99);
    assert_eq!(base.w1.data(), tuned.w1.data());
    assert_eq!(base.w2.data(), tuned.w2.data());
    assert_ne!(base.w3, tuned.w3);
}
