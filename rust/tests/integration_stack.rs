//! Integration tests over the full stack: artifact bundle → PJRT → native
//! engines → serving coordinator. These REQUIRE `make artifacts` (the
//! Makefile's `test` target guarantees the ordering); they fail loudly if
//! the bundle is missing rather than silently skipping. They also require
//! the `xla` cargo feature (PJRT), which the offline default build cannot
//! provide — the whole file is compiled out without it.
#![cfg(feature = "xla")]

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use pcilt::coordinator::{run_poisson, BackendSpec, NativeEngineKind, Server, ServerOpts};
use pcilt::model::{EngineChoice, QuantCnn};
use pcilt::runtime::{ArtifactBundle, PjrtContext};
use pcilt::tensor::{Shape4, Tensor4};

fn artifact_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn bundle() -> ArtifactBundle {
    ArtifactBundle::load(&artifact_dir())
        .expect("artifacts missing — run `make artifacts` before `cargo test`")
}

fn slice_image(codes: &Tensor4<u8>, i: usize) -> Tensor4<u8> {
    let s = codes.shape();
    Tensor4::from_fn(Shape4::new(1, s.h, s.w, s.c), |_, h, w, c| {
        codes.get(i, h, w, c)
    })
}

#[test]
fn full_stack_bit_exact_python_pjrt_native() {
    let b = bundle();
    let (codes, expect, _) = b.smoke_pair().unwrap();

    // PJRT executes the AOT artifact...
    let ctx = PjrtContext::cpu().unwrap();
    let exe = ctx.load_hlo(&b.hlo_path("pcilt", 8).unwrap()).unwrap();
    let pjrt: Vec<i32> = exe
        .infer(&codes, b.params.classes)
        .unwrap()
        .into_iter()
        .flatten()
        .collect();
    assert_eq!(pjrt, expect, "PJRT != python");

    // ...and every native engine agrees bit-for-bit.
    for choice in [
        EngineChoice::Dm,
        EngineChoice::Pcilt,
        EngineChoice::Segment { seg_n: 2 },
        EngineChoice::Segment { seg_n: 4 },
        EngineChoice::Shared,
    ] {
        let m = QuantCnn::new(b.params.clone(), choice);
        let native: Vec<i32> = m.forward(&codes).into_iter().flatten().collect();
        assert_eq!(native, expect, "native {} != python", m.engine_name());
    }
}

#[test]
fn dm_and_pcilt_artifacts_agree() {
    let b = bundle();
    let (codes, _, _) = b.smoke_pair().unwrap();
    let ctx = PjrtContext::cpu().unwrap();
    let a = ctx.load_hlo(&b.hlo_path("pcilt", 8).unwrap()).unwrap();
    let d = ctx.load_hlo(&b.hlo_path("dm", 8).unwrap()).unwrap();
    assert_eq!(
        a.infer(&codes, b.params.classes).unwrap(),
        d.infer(&codes, b.params.classes).unwrap(),
        "pcilt and dm artifacts disagree"
    );
}

#[test]
fn trained_model_classifies_smoke_batch() {
    let b = bundle();
    let (codes, _, labels) = b.smoke_pair().unwrap();
    let m = QuantCnn::new(b.params.clone(), EngineChoice::Pcilt);
    let preds = m.classify(&codes);
    let correct = preds
        .iter()
        .zip(labels.iter())
        .filter(|(p, l)| **p == **l as usize)
        .count();
    // Trained to ~100% on the synthetic task; demand at least 6/8 to leave
    // margin for retraining variance.
    assert!(correct >= 6, "only {correct}/8 correct");
}

#[test]
fn serving_hlo_pool_end_to_end() {
    let b = bundle();
    let img = b.params.img;
    let act_bits = b.params.act_bits;
    let server = Arc::new(
        Server::start(
            BackendSpec::hlo(b.clone(), "pcilt"),
            &ServerOpts {
                workers: 2,
                max_batch: 8,
                batch_deadline: Duration::from_micros(1000),
                queue_capacity: 512,
            },
        )
        .unwrap(),
    );
    let report = run_poisson(&server, 1000.0, 200, img, act_bits, 0x11);
    assert_eq!(report.accepted + report.rejected, 200);
    let m = server.metrics();
    assert_eq!(m.completed as usize, report.accepted);
    assert!(m.p50_latency_ns > 0.0);
}

#[test]
fn serving_answers_match_native_under_concurrency() {
    let b = bundle();
    let (codes, _, _) = b.smoke_pair().unwrap();
    let native = QuantCnn::new(b.params.clone(), EngineChoice::Pcilt);
    let server = Arc::new(
        Server::start(
            BackendSpec::hlo(b.clone(), "pcilt"),
            &ServerOpts {
                workers: 3,
                max_batch: 4,
                batch_deadline: Duration::from_micros(500),
                queue_capacity: 256,
            },
        )
        .unwrap(),
    );
    // Fire all 8 smoke images from 4 threads repeatedly; every response
    // must equal the native engine's logits for that image.
    let mut handles = Vec::new();
    for t in 0..4 {
        let server = Arc::clone(&server);
        let images: Vec<Tensor4<u8>> = (0..8).map(|i| slice_image(&codes, i)).collect();
        let expect: Vec<Vec<i32>> = images
            .iter()
            .map(|img| native.forward(img).remove(0))
            .collect();
        handles.push(std::thread::spawn(move || {
            for round in 0..5 {
                for (i, img) in images.iter().enumerate() {
                    let resp = server.infer_blocking(img.clone()).unwrap();
                    assert_eq!(
                        resp.logits, expect[i],
                        "thread {t} round {round} image {i}: wrong answer"
                    );
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn failure_injection_malformed_artifact_rejected() {
    // A corrupted HLO file must fail compilation, not crash the process.
    let tmp = std::env::temp_dir().join("pcilt_bad_hlo");
    std::fs::create_dir_all(&tmp).unwrap();
    let bad = tmp.join("bad.hlo.txt");
    std::fs::write(&bad, "HloModule garbage\nENTRY nope {\n}").unwrap();
    let ctx = PjrtContext::cpu().unwrap();
    assert!(ctx.load_hlo(&bad).is_err());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn failure_injection_truncated_weights_rejected() {
    // Copy the bundle, truncate weights.bin: loader must detect it.
    let src = artifact_dir();
    let tmp = std::env::temp_dir().join("pcilt_truncated_bundle");
    std::fs::create_dir_all(&tmp).unwrap();
    for f in std::fs::read_dir(&src).unwrap() {
        let f = f.unwrap();
        std::fs::copy(f.path(), tmp.join(f.file_name())).unwrap();
    }
    let weights = std::fs::read(tmp.join("weights.bin")).unwrap();
    std::fs::write(tmp.join("weights.bin"), &weights[..weights.len() / 2]).unwrap();
    assert!(ArtifactBundle::load(&tmp).is_err());
    std::fs::remove_dir_all(&tmp).ok();
}

#[test]
fn hlo_batch1_and_batch8_agree() {
    let b = bundle();
    let (codes, expect, _) = b.smoke_pair().unwrap();
    let ctx = PjrtContext::cpu().unwrap();
    let b1 = ctx.load_hlo(&b.hlo_path("pcilt", 1).unwrap()).unwrap();
    for i in 0..8 {
        let one = slice_image(&codes, i);
        let logits = b1.infer(&one, b.params.classes).unwrap();
        assert_eq!(logits[0], expect[i * 8..(i + 1) * 8].to_vec(), "image {i}");
    }
}
