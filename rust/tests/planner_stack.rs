//! Planner + parallel-execution integration tests: the auto-selection
//! registry ranks engines the way the paper's economics say it should, and
//! whatever the planner picks stays bit-exact with the DM baseline —
//! including under batch-parallel execution and through the serving
//! coordinator's `auto` backend.

use std::sync::Arc;
use std::time::Duration;

use pcilt::coordinator::{BackendSpec, NativeEngineKind, Server, ServerOpts};
use pcilt::model::{random_params, EngineChoice, QuantCnn};
use pcilt::pcilt::dm::conv_reference;
use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::parallel::conv_parallel;
use pcilt::pcilt::planner::{EngineId, EnginePlanner, LayerSpec, PlannerPolicy};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::propcheck::forall;

/// The paper's headline regime: low-cardinality activations over a large
/// receptive field — lookup tables must out-rank direct multiplication.
#[test]
fn registry_ranks_pcilt_above_dm_on_low_bit_large_rf() {
    let planner = EnginePlanner::default();
    for (bits, k, side) in [(1u32, 5usize, 96usize), (2, 5, 64), (4, 3, 64)] {
        let spec = LayerSpec {
            geom: ConvGeometry::unit_stride(k, k),
            in_ch: 1,
            out_ch: 8,
            act_bits: bits,
            weight_bits: 8,
            input: Shape4::new(1, side, side, 1),
        };
        let plan = planner.plan_layer(&spec, None);
        let pcilt = plan.candidate(EngineId::Pcilt).unwrap().score;
        let dm = plan.candidate(EngineId::Dm).unwrap().score;
        assert!(
            pcilt < dm,
            "a{bits} k{k} {side}x{side}: pcilt {pcilt} !< dm {dm}"
        );
    }
}

/// The paper's own CPU caveat: wide activations and a tiny workload flip
/// the crossover back to DM (tables spill cache, builds cannot amortize).
#[test]
fn registry_ranks_dm_above_pcilt_on_high_bit_tiny_layer() {
    let planner = EnginePlanner::default();
    let spec = LayerSpec {
        geom: ConvGeometry::unit_stride(3, 3),
        in_ch: 8,
        out_ch: 32,
        act_bits: 8,
        weight_bits: 8,
        input: Shape4::new(1, 8, 8, 8),
    };
    let plan = planner.plan_layer(&spec, None);
    let pcilt = plan.candidate(EngineId::Pcilt).unwrap().score;
    let dm = plan.candidate(EngineId::Dm).unwrap().score;
    assert!(dm < pcilt, "dm {dm} !< pcilt {pcilt}");
}

/// Whatever the planner selects computes the same convolution as the DM
/// engine, bit for bit, across random layer shapes and cardinalities.
#[test]
fn planner_selected_engines_match_dm_bit_for_bit() {
    forall("planner choice == dm reference", 20, |g| {
        let mut rng = Rng::new(g.i64(0, i64::MAX / 2) as u64);
        let bits = *rng.choose(&[1u32, 2, 4, 8]);
        let (kh, kw) = *rng.choose(&[(3usize, 3usize), (5, 5)]);
        let ic = rng.range_i64(1, 3) as usize;
        let oc = rng.range_i64(1, 4) as usize;
        let h = kh + rng.range_i64(0, 6) as usize;
        let wd = kw + rng.range_i64(0, 6) as usize;
        let x = Tensor4::random_activations(Shape4::new(2, h, wd, ic), bits, &mut rng);
        let w = Tensor4::random_weights(Shape4::new(oc, kh, kw, ic), 8, &mut rng);
        let spec = LayerSpec::for_weights(&w, bits, x.shape());
        let planner = EnginePlanner::default();
        let engine = planner.choose(&w, &spec);
        let expect = conv_reference(&x, &w, spec.geom);
        assert_eq!(engine.conv(&x), expect, "engine {}", engine.name());
        // and the parallel path over the same engine agrees too
        assert_eq!(
            conv_parallel(engine.as_ref(), &x, 4),
            expect,
            "parallel {}",
            engine.name()
        );
    });
}

/// Turning the amortization knob all the way down forces the planner to
/// respect one-shot build costs; all the way up, serving economics win.
#[test]
fn amortization_knob_moves_the_crossover() {
    let spec = LayerSpec {
        geom: ConvGeometry::unit_stride(3, 3),
        in_ch: 2,
        out_ch: 4,
        act_bits: 8,
        weight_bits: 8,
        input: Shape4::new(1, 10, 10, 2),
    };
    let one_shot = EnginePlanner::new(PlannerPolicy {
        amortize_invocations: 1.0,
        ..PlannerPolicy::default()
    });
    let serving = EnginePlanner::new(PlannerPolicy {
        amortize_invocations: 1e9,
        ..PlannerPolicy::default()
    });
    let p1 = one_shot.plan_layer(&spec, None);
    let p2 = serving.plan_layer(&spec, None);
    let score_1 = p1.candidate(EngineId::Pcilt).unwrap().score;
    let score_2 = p2.candidate(EngineId::Pcilt).unwrap().score;
    assert!(
        score_2 < score_1,
        "amortization must lower table-engine scores ({score_2} !< {score_1})"
    );
}

/// End-to-end: the coordinator's `auto` backend serves answers identical
/// to a DM pool over the same weights.
#[test]
fn auto_backend_serves_dm_identical_answers() {
    let mut rng = Rng::new(77);
    let params = random_params(4, &mut rng);
    let reference = QuantCnn::new(params.clone(), EngineChoice::Dm);
    let server = Arc::new(
        Server::start(
            BackendSpec::native(params, NativeEngineKind::Auto),
            &ServerOpts {
                workers: 2,
                max_batch: 8,
                batch_deadline: Duration::from_micros(500),
                queue_capacity: 128,
            },
        )
        .unwrap(),
    );
    for i in 0..12 {
        let img = Tensor4::random_activations(Shape4::new(1, 16, 16, 1), 4, &mut rng);
        let resp = server.infer_blocking(img.clone()).unwrap();
        assert_eq!(resp.logits, reference.forward(&img)[0], "request {i}");
    }
    let m = server.metrics();
    assert_eq!(m.completed, 12);
}
