//! Fused code-domain pipeline stack: the ISSUE-5 acceptance criteria.
//!
//! The fused stage walk (tiled conv→requantize→pool chains passing codes,
//! absorbed-requantize tables) must be bit-identical to the unfused
//! per-stage reference walk AND to the DM reference, across engines,
//! cardinalities, odd/even geometries and pool variants; fused-chain
//! table keys recorded by `compile` must be exactly the store's resident
//! keys; and the golden-vector fixtures (generated outside the crate by
//! `python/tools/gen_golden.py`) must reproduce through the fused walk.

mod common;

use std::sync::Arc;

use common::{golden_spec, load_golden, write_golden, GoldenCase, GOLDEN_FIXTURES};
use pcilt::model::{EngineChoice, NetworkSpec, StageSpec};
use pcilt::pcilt::planner::EnginePlanner;
use pcilt::pcilt::TableStore;
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::propcheck::forall;

fn images(n: usize, img: usize, in_ch: usize, bits: u32, seed: u64) -> Tensor4<u8> {
    let mut rng = Rng::new(seed);
    Tensor4::random_activations(Shape4::new(n, img, img, in_ch), bits, &mut rng)
}

/// A 2-conv spec with an optional pool between the chains.
fn two_conv_spec(
    act_bits: u32,
    img: usize,
    engines: [EngineChoice; 2],
    pool: Option<(usize, bool)>,
) -> NetworkSpec {
    let mut stages = vec![
        StageSpec::Conv { out_ch: 4, kernel: 3, stride: 1, engine: engines[0] },
        StageSpec::Requantize { scale: 0.0625 },
    ];
    if let Some((k, floor)) = pool {
        stages.push(StageSpec::MaxPool { k, floor });
    }
    stages.extend([
        StageSpec::Conv { out_ch: 3, kernel: 3, stride: 1, engine: engines[1] },
        StageSpec::Requantize { scale: 0.09375 },
        StageSpec::Dense { classes: 6 },
    ]);
    NetworkSpec {
        act_bits,
        img,
        in_ch: 1,
        stages,
    }
}

/// The headline property: fused == unfused == DM reference, bit for bit,
/// across engines (Dm/Pcilt/Shared/Segment/Auto at the spec level; the
/// Mixed and RowSegment engines are pinned at the `run_chain` level in
/// `pcilt::fused` unit tests), act_bits in {2,4,8}, odd/even image sizes
/// and pool-k variants, serial and parallel.
#[test]
fn fused_walk_bit_identical_property_sweep() {
    let engines = [
        EngineChoice::Dm,
        EngineChoice::Pcilt,
        EngineChoice::Shared,
        EngineChoice::Segment { seg_n: 2 },
        EngineChoice::Auto,
    ];
    forall("fused == unfused == dm across the grid", 10, |g| {
        let act_bits = *g.rng().choose(&[2u32, 4, 8]);
        let img = g.usize(11, 18); // odd and even sizes
        let pool = match g.usize(0, 3) {
            0 => None,
            1 => Some((2usize, (img - 2) % 2 != 0)), // strict when it tiles
            2 => Some((2usize, true)),               // always-floor variant
            _ => Some((3usize, (img - 2) % 3 != 0)),
        };
        let e0 = *g.rng().choose(&engines);
        let e1 = *g.rng().choose(&engines);
        let spec = two_conv_spec(act_bits, img, [e0, e1], pool);
        let dm_spec = two_conv_spec(act_bits, img, [EngineChoice::Dm; 2], pool);
        let weights = spec.seeded_weights(g.rng().below(1 << 20)).unwrap();
        let store = Arc::new(TableStore::new());
        let net = spec.compile_with_defaults(&weights, &store).unwrap();
        let reference = dm_spec
            .compile_with_defaults(&weights, &Arc::new(TableStore::new()))
            .unwrap()
            .with_fused(false);
        let x = images(3, img, 1, act_bits, g.rng().below(1 << 20));
        let expect = reference.forward_serial(&x);
        let label = format!("a{act_bits} img{img} pool{pool:?} {e0:?}+{e1:?}");
        assert_eq!(net.forward_fused_serial(&x), expect, "fused serial ({label})");
        assert_eq!(net.forward_serial(&x), expect, "unfused serial ({label})");
        assert_eq!(net.forward(&x), expect, "fused parallel default ({label})");
        let threaded = spec
            .compile_with_defaults(&weights, &store)
            .unwrap()
            .with_threads(3);
        assert_eq!(threaded.forward(&x), expect, "fused 3-thread ({label})");
    });
}

/// Regression: the fused-chain table keys `compile` records (engine
/// tables + absorbed-requantize tables) are exactly the store's resident
/// keys, and the planning pass predicts the identical list.
#[test]
fn fused_chain_keys_recorded_by_compile_match_store() {
    let spec = two_conv_spec(4, 14, [EngineChoice::Pcilt, EngineChoice::Shared], Some((2, false)));
    let weights = spec.seeded_weights(55).unwrap();
    let store = Arc::new(TableStore::new());
    let planner = EnginePlanner::with_store(
        pcilt::pcilt::planner::default_policy(),
        store.clone(),
    );
    let plan = spec
        .plan(&weights, &planner, pcilt::pcilt::planner::default_plan_batch())
        .unwrap();
    let predicted = plan.table_keys();
    assert_eq!(
        predicted.len(),
        4,
        "two lookup-family chains: engine tables + absorbed requant each"
    );
    let net = spec.compile_planned(&weights, &plan, &store).unwrap();
    assert_eq!(net.table_keys(), predicted.as_slice(), "compile drifted from its plan");
    assert_eq!(net.absorbed_requant_count(), 2);
    for k in net.table_keys() {
        assert!(store.contains(*k), "recorded key missing from store");
    }
    assert_eq!(store.stats().entries as usize, predicted.len());

    // DM chains stay table-free: no engine tables, no absorbed requant.
    let dm_spec = two_conv_spec(4, 14, [EngineChoice::Dm; 2], Some((2, false)));
    let dm = dm_spec
        .compile_with_defaults(&weights, &Arc::new(TableStore::new()))
        .unwrap();
    assert!(dm.table_keys().is_empty());
    assert_eq!(dm.absorbed_requant_count(), 0);
}

/// Golden-vector conformance: fixtures produced by an independent numpy
/// implementation of the pipeline reproduce bit-for-bit through the fused
/// walk (and the unfused walk) for every engine choice.
#[test]
fn golden_fixtures_reproduce_through_fused_walk() {
    for &name in GOLDEN_FIXTURES {
        let case = load_golden(name);
        for engine in [EngineChoice::Dm, EngineChoice::Pcilt, EngineChoice::Auto] {
            let spec = golden_spec(name, engine);
            spec.validate().unwrap();
            let net = spec
                .compile_with_defaults(&case.weights, &Arc::new(TableStore::new()))
                .unwrap();
            assert_eq!(
                net.forward_fused_serial(&case.input),
                case.logits,
                "{name} fused walk vs golden ({engine:?})"
            );
            assert_eq!(
                net.forward_serial(&case.input),
                case.logits,
                "{name} unfused walk vs golden ({engine:?})"
            );
        }
    }
}

/// The floored-pool fixture actually exercises the truncating boundary:
/// its strict twin must be rejected at validation.
#[test]
fn golden_floor_fixture_pins_the_boundary() {
    let spec = golden_spec("g2_pool_floor", EngineChoice::Dm);
    let strict = NetworkSpec {
        stages: spec
            .stages
            .iter()
            .map(|s| match s {
                StageSpec::MaxPool { k, .. } => StageSpec::MaxPool { k: *k, floor: false },
                other => other.clone(),
            })
            .collect(),
        ..spec
    };
    let err = strict.validate().unwrap_err();
    assert!(err.to_string().contains("does not tile"), "{err}");
}

/// Regenerate the golden fixtures' expected logits from the in-process DM
/// reference (weights and inputs are kept from the checked-in files).
/// Run explicitly after an intentional pipeline-semantics change:
/// `cargo test --test fused_stack -- --ignored regenerate`.
#[test]
#[ignore]
fn regenerate_golden_fixtures() {
    for &name in GOLDEN_FIXTURES {
        let case = load_golden(name);
        let spec = golden_spec(name, EngineChoice::Dm);
        let net = spec
            .compile_with_defaults(&case.weights, &Arc::new(TableStore::new()))
            .unwrap()
            .with_fused(false);
        let logits = net.forward_serial(&case.input);
        write_golden(
            name,
            &GoldenCase {
                weights: case.weights,
                input: case.input,
                logits,
            },
        );
    }
}
