//! NetworkSpec stack: the ISSUE-4 acceptance criteria.
//!
//! The compat `NetworkSpec` must reproduce seed `QuantCnn` outputs
//! bit-for-bit across every engine choice; a 4-conv spec with
//! heterogeneous per-stage engines must be bit-exact vs the DM reference;
//! compile-time table keys must equal what the store actually builds; and
//! a 4-conv network declared purely in TOML must serve end-to-end through
//! the `ModelRegistry` with planner-chosen per-stage engines.

mod common;

use std::sync::Arc;
use std::time::Duration;

use common::{golden_spec, load_golden, GOLDEN_FIXTURES};
use pcilt::config::{Document, ServeConfig};
use pcilt::coordinator::{ModelRegistry, ServerOpts};
use pcilt::model::{
    random_params_seeded, EngineChoice, NetworkSpec, QuantCnn, StageSpec,
};
use pcilt::pcilt::planner::EnginePlanner;
use pcilt::pcilt::TableStore;
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::propcheck::forall;

fn images(n: usize, img: usize, bits: u32, seed: u64) -> Tensor4<u8> {
    let mut rng = Rng::new(seed);
    Tensor4::random_activations(Shape4::new(n, img, img, 1), bits, &mut rng)
}

/// Property: for every engine choice, the compat spec (what `QuantCnn` now
/// compiles through) is bit-for-bit the original seed model — across
/// random weights, random inputs, serial and parallel forward.
#[test]
fn compat_spec_reproduces_quantcnn_bit_for_bit() {
    forall("compat NetworkSpec == QuantCnn", 12, |g| {
        let weight_seed = g.rng().below(1 << 20);
        let input_seed = g.rng().below(1 << 20);
        let act_bits = g.usize(1, 4) as u32;
        let batch = g.usize(1, 5);
        let params = random_params_seeded(act_bits, weight_seed);
        let codes = images(batch, params.img, act_bits, input_seed);
        let reference = {
            let store = Arc::new(TableStore::new());
            QuantCnn::with_store(params.clone(), EngineChoice::Dm, &store).forward(&codes)
        };
        for choice in [
            EngineChoice::Dm,
            EngineChoice::Pcilt,
            EngineChoice::Segment { seg_n: 2 },
            EngineChoice::Shared,
            EngineChoice::Auto,
        ] {
            let (spec, weights) = NetworkSpec::quantcnn(&params, choice);
            let store = Arc::new(TableStore::new());
            let net = spec.compile_with_defaults(&weights, &store).unwrap();
            assert_eq!(
                net.forward(&codes),
                reference,
                "engine {} (weights {weight_seed}, inputs {input_seed}, a{act_bits})",
                net.engine_name()
            );
            // serial == parallel: the single stage-walk pin
            assert_eq!(net.with_threads(4).forward(&codes), reference);
        }
    });
}

/// A deeper 4-conv spec with a different engine at every stage is
/// bit-exact vs the all-DM compile of the same weights — the paper's
/// per-layer heterogeneity claim at depth.
#[test]
fn four_conv_heterogeneous_spec_is_bit_exact_vs_dm() {
    let with_engines = |engines: [EngineChoice; 4]| NetworkSpec {
        act_bits: 2,
        img: 24,
        in_ch: 1,
        stages: vec![
            StageSpec::Conv { out_ch: 6, kernel: 3, stride: 1, engine: engines[0] },
            StageSpec::Requantize { scale: 0.04 },
            StageSpec::Conv { out_ch: 8, kernel: 3, stride: 1, engine: engines[1] },
            StageSpec::Requantize { scale: 0.04 },
            StageSpec::MaxPool { k: 2, floor: false },
            StageSpec::Conv { out_ch: 8, kernel: 3, stride: 1, engine: engines[2] },
            StageSpec::Requantize { scale: 0.04 },
            StageSpec::Conv { out_ch: 4, kernel: 3, stride: 1, engine: engines[3] },
            StageSpec::Requantize { scale: 0.04 },
            StageSpec::Dense { classes: 10 },
        ],
    };
    let hetero = with_engines([
        EngineChoice::Pcilt,
        EngineChoice::Segment { seg_n: 2 },
        EngineChoice::Shared,
        EngineChoice::Auto,
    ]);
    let dm = with_engines([EngineChoice::Dm; 4]);
    let weights = hetero.seeded_weights(77).unwrap();
    let net = hetero
        .compile_with_defaults(&weights, &Arc::new(TableStore::new()))
        .unwrap();
    let reference = dm
        .compile_with_defaults(&weights, &Arc::new(TableStore::new()))
        .unwrap();
    assert_eq!(net.conv_engine_names().len(), 4);
    assert_ne!(net.engine_name(), "dm", "{}", net.engine_name());
    for seed in 0..4 {
        let x = images(3, 24, 2, 500 + seed);
        assert_eq!(net.forward(&x), reference.forward(&x), "input seed {seed}");
    }
}

/// Compile-time table keys == the keys the store actually holds after the
/// build — the drift-proof replacement for the old `planned_table_keys`
/// mirror.
#[test]
fn compiled_keys_are_the_store_contents() {
    let spec = NetworkSpec {
        act_bits: 2,
        img: 20,
        in_ch: 1,
        stages: vec![
            StageSpec::Conv { out_ch: 4, kernel: 3, stride: 1, engine: EngineChoice::Pcilt },
            StageSpec::Requantize { scale: 0.05 },
            StageSpec::Conv { out_ch: 4, kernel: 3, stride: 1, engine: EngineChoice::Auto },
            StageSpec::Requantize { scale: 0.05 },
            StageSpec::Conv { out_ch: 4, kernel: 3, stride: 1, engine: EngineChoice::Dm },
            StageSpec::Requantize { scale: 0.05 },
            StageSpec::Dense { classes: 4 },
        ],
    };
    let weights = spec.seeded_weights(9).unwrap();
    let store = Arc::new(TableStore::new());
    // the plan predicts…
    let planner = EnginePlanner::with_store(
        pcilt::pcilt::planner::default_policy(),
        store.clone(),
    );
    let predicted = spec
        .plan(&weights, &planner, pcilt::pcilt::planner::default_plan_batch())
        .unwrap()
        .table_keys();
    // …compile records the same keys, and the store holds exactly them.
    let net = spec.compile_with_defaults(&weights, &store).unwrap();
    assert_eq!(net.table_keys(), predicted.as_slice());
    for k in net.table_keys() {
        assert!(store.contains(*k));
    }
    assert_eq!(store.stats().entries as usize, net.table_keys().len());
}

/// Golden-vector conformance for the unfused reference walk: fixtures
/// generated by an independent numpy implementation of the pipeline
/// (`python/tools/gen_golden.py`) reproduce bit-for-bit, so the
/// conformance anchor no longer rests solely on the in-process DM
/// reference agreeing with itself.
#[test]
fn golden_fixtures_reproduce_through_unfused_reference() {
    for &name in GOLDEN_FIXTURES {
        let case = load_golden(name);
        let spec = golden_spec(name, EngineChoice::Dm);
        let net = spec
            .compile_with_defaults(&case.weights, &Arc::new(TableStore::new()))
            .unwrap()
            .with_fused(false);
        assert_eq!(
            net.forward_serial(&case.input),
            case.logits,
            "{name}: unfused DM walk diverged from the independent reference"
        );
    }
}

/// The headline acceptance criterion: a 4-conv `NetworkSpec` declared
/// purely in TOML serves end-to-end through the `ModelRegistry` with
/// planner-chosen per-stage engines, bit-identical to the DM reference.
#[test]
fn toml_declared_4conv_network_serves_bit_exact() {
    let doc = Document::parse(
        r#"
[serve]
workers = 2
max_batch = 4
[[models]]
name = "deep4"
engine = "auto"
act_bits = 2
seed = 123
img = 24
[[models.layers]]
type = "conv"
out_ch = 6
kernel = 3
scale = 0.04
[[models.layers]]
type = "conv"
out_ch = 8
kernel = 3
scale = 0.04
[[models.layers]]
type = "pool"
k = 2
[[models.layers]]
type = "conv"
out_ch = 8
kernel = 3
scale = 0.04
[[models.layers]]
type = "conv"
out_ch = 4
kernel = 3
scale = 0.04
[[models.layers]]
type = "dense"
classes = 10
"#,
    )
    .unwrap();
    let cfg = ServeConfig::from_document(&doc).unwrap();
    assert_eq!(cfg.models.len(), 1);
    let m = &cfg.models[0];
    assert_eq!(m.layers.len(), 10, "4 convs + 4 desugared requants + pool + dense");
    let spec = m.network_spec().unwrap();
    assert_eq!(spec.conv_count(), 4);

    let store = Arc::new(TableStore::new());
    let registry = ModelRegistry::start_with_store(
        &cfg.models,
        &ServerOpts {
            workers: cfg.workers,
            max_batch: cfg.max_batch,
            batch_deadline: Duration::from_millis(1),
            queue_capacity: 64,
        },
        store.clone(),
    )
    .unwrap();

    // DM reference over the same declared spec + seeded weights.
    let entry = registry.model("deep4").unwrap();
    let dm_spec = NetworkSpec {
        stages: entry
            .spec
            .stages
            .iter()
            .map(|s| match s {
                StageSpec::Conv { out_ch, kernel, stride, .. } => StageSpec::Conv {
                    out_ch: *out_ch,
                    kernel: *kernel,
                    stride: *stride,
                    engine: EngineChoice::Dm,
                },
                other => other.clone(),
            })
            .collect(),
        ..entry.spec.clone()
    };
    let reference = dm_spec
        .compile_with_defaults(&entry.weights, &Arc::new(TableStore::new()))
        .unwrap();

    for i in 0..8 {
        let img = images(1, 24, 2, 900 + i);
        let (_, rx) = registry.route(Some("deep4"), None, img.clone()).unwrap();
        let resp = rx.recv().unwrap();
        assert_eq!(resp.model, "deep4");
        assert_eq!(
            resp.logits,
            reference.forward(&img)[0],
            "served logits != DM reference (request {i})"
        );
    }
    // every all-auto stage resolved through the planner to an exact engine
    let served = entry
        .spec
        .compile_with_defaults(&entry.weights, &store)
        .unwrap();
    let names = served.conv_engine_names();
    assert_eq!(names.len(), 4);
    assert!(
        !names.iter().any(|n| n.contains("winograd") || n.contains("fft")),
        "planner must only pick exact engines: {names:?}"
    );
    registry.shutdown();
}
