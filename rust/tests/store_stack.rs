//! TableStore integration tests: store-borrowed engines are bit-identical
//! to owning engines, persistence roundtrips exactly, eviction rebuilds
//! correctly under a tiny budget, and a model loaded twice (or a "server
//! restart" against a persisted cache dir) performs zero redundant table
//! builds — the PR's acceptance criteria, verified by store counters.

use std::path::PathBuf;
use std::sync::Arc;

use pcilt::model::{random_params, EngineChoice, QuantCnn};
use pcilt::pcilt::dm::conv_reference;
use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::planner::{EngineId, EnginePlanner, LayerSpec, PlannerPolicy};
use pcilt::pcilt::{
    ChannelWidths, ConvFunc, MixedEngine, PciltEngine, RowSegmentEngine, SegmentEngine,
    SharedEngine, TableKey, TableStore,
};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::propcheck::forall;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pcilt_store_stack_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Property: every store-borrowed table engine computes the same
/// convolution as its table-owning twin, bit for bit, across random
/// shapes and cardinalities — and the second borrow never rebuilds.
#[test]
fn store_borrowed_engines_match_owned_bit_for_bit() {
    forall("store == owned for every engine", 15, |g| {
        let mut rng = Rng::new(g.i64(0, i64::MAX / 2) as u64);
        let bits = *rng.choose(&[1u32, 2, 4]);
        let (kh, kw) = *rng.choose(&[(3usize, 3usize), (5, 5)]);
        let ic = rng.range_i64(1, 2) as usize;
        let oc = rng.range_i64(1, 3) as usize;
        let h = kh + rng.range_i64(0, 4) as usize;
        let wd = kw + rng.range_i64(0, 4) as usize;
        let x = Tensor4::random_activations(Shape4::new(2, h, wd, ic), bits, &mut rng);
        let w = Tensor4::random_weights(Shape4::new(oc, kh, kw, ic), 8, &mut rng);
        let geom = ConvGeometry::unit_stride(kh, kw);
        let expect = conv_reference(&x, &w, geom);
        let f = ConvFunc::Mul;

        let store = TableStore::new();
        let engines: Vec<(&str, Box<dyn ConvEngine>, Box<dyn ConvEngine>)> = vec![
            (
                "pcilt",
                Box::new(PciltEngine::new(&w, bits, geom)),
                Box::new(PciltEngine::from_store(&store, &w, bits, geom, &f)),
            ),
            (
                "shared",
                Box::new(SharedEngine::new(&w, bits, geom)),
                Box::new(SharedEngine::from_store(&store, &w, bits, geom, &f)),
            ),
            (
                "segment",
                Box::new(SegmentEngine::new(&w, bits, 2, geom)),
                Box::new(SegmentEngine::from_store(&store, &w, bits, 2, geom, &f)),
            ),
            (
                "segment-row",
                Box::new(RowSegmentEngine::new(&w, bits, 2, geom)),
                Box::new(RowSegmentEngine::from_store(&store, &w, bits, 2, geom, &f)),
            ),
            (
                "mixed",
                Box::new(MixedEngine::new(&w, ChannelWidths::uniform(ic, bits), geom)),
                Box::new(MixedEngine::from_store(
                    &store,
                    &w,
                    ChannelWidths::uniform(ic, bits),
                    bits,
                    geom,
                    &f,
                )),
            ),
        ];
        let builds_after_first = store.stats().builds;
        for (name, owned, borrowed) in &engines {
            assert_eq!(owned.conv(&x), expect, "{name} owned != reference");
            assert_eq!(borrowed.conv(&x), expect, "{name} borrowed != reference");
        }
        // Borrowing the same content again must be all hits, no builds.
        let again = PciltEngine::from_store(&store, &w, bits, geom, &f);
        assert_eq!(again.conv(&x), expect);
        assert_eq!(store.stats().builds, builds_after_first, "rebuild on second borrow");
    });
}

/// Persistence roundtrip: save -> load -> identical entries (checksum
/// verified), and every engine built from the loaded store is
/// bit-identical to one built fresh.
#[test]
fn persistence_roundtrip_is_bit_identical() {
    let dir = temp_dir("roundtrip");
    let mut rng = Rng::new(101);
    let x = Tensor4::random_activations(Shape4::new(2, 7, 7, 2), 2, &mut rng);
    let w = Tensor4::random_weights(Shape4::new(3, 3, 3, 2), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;

    let store = TableStore::new();
    let fresh_pcilt = PciltEngine::from_store(&store, &w, 2, geom, &f);
    let fresh_shared = SharedEngine::from_store(&store, &w, 2, geom, &f);
    let fresh_segment = SegmentEngine::from_store(&store, &w, 2, 4, geom, &f);
    let report = store.save(&dir).unwrap();
    assert_eq!(report.entries, 3);

    // "Server restart": a brand-new store warms from the cache dir.
    let restarted = TableStore::new();
    assert_eq!(restarted.load(&dir).unwrap(), 3);
    let loaded_pcilt = PciltEngine::from_store(&restarted, &w, 2, geom, &f);
    let loaded_shared = SharedEngine::from_store(&restarted, &w, 2, geom, &f);
    let loaded_segment = SegmentEngine::from_store(&restarted, &w, 2, 4, geom, &f);
    let stats = restarted.stats();
    assert_eq!(stats.builds, 0, "warm boot must perform zero table builds");
    assert_eq!(stats.loads, 3);
    assert_eq!(stats.hits, 3);

    assert_eq!(loaded_pcilt.conv(&x), fresh_pcilt.conv(&x));
    assert_eq!(loaded_shared.conv(&x), fresh_shared.conv(&x));
    assert_eq!(loaded_segment.conv(&x), fresh_segment.conv(&x));

    // Saving the restarted store reproduces the byte-identical cache.
    let dir2 = temp_dir("roundtrip2");
    let report2 = restarted.save(&dir2).unwrap();
    assert_eq!(report2.checksum, report.checksum, "cache must be deterministic");
    assert_eq!(report2.payload_bytes, report.payload_bytes);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// Eviction under a tiny budget: the store sheds LRU entries, stays
/// correct, and transparently rebuilds on the next request.
#[test]
fn eviction_then_rebuild_is_correct() {
    let mut rng = Rng::new(103);
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    let x = Tensor4::random_activations(Shape4::new(1, 6, 6, 1), 4, &mut rng);
    let ws: Vec<Tensor4<i8>> = (0..4)
        .map(|_| Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng))
        .collect();
    let expects: Vec<_> = ws.iter().map(|w| conv_reference(&x, w, geom)).collect();

    // Budget fits roughly one layer's tables: 2 oc * 9 pos * 16 card * 4 B
    // (plus mirror) ~= 2.3 KiB; give it 4 KiB.
    let store = TableStore::with_budget(4 * 1024);
    for round in 0..3 {
        for (w, expect) in ws.iter().zip(&expects) {
            // Engine dropped at the end of each iteration, so its entry is
            // evictable when the next build pushes past the budget.
            let e = PciltEngine::from_store(&store, w, 4, geom, &f);
            assert_eq!(e.conv(&x), *expect, "round {round}");
        }
    }
    let stats = store.stats();
    assert!(stats.evictions > 0, "tiny budget must evict: {stats:?}");
    assert!(
        stats.builds > 4,
        "evicted entries must rebuild on miss: {stats:?}"
    );
    // Derived views (channels-last mirrors) grow entries after insert;
    // re-applying the budget evicts back under it now that no engine
    // borrows anything.
    store.set_budget_bytes(4 * 1024);
    let stats = store.stats();
    assert!(
        stats.bytes <= 4.0 * 1024.0,
        "resident bytes {} over budget with nothing borrowed",
        stats.bytes
    );
}

/// The headline criterion: a model loaded twice performs zero redundant
/// table builds, and a "restarted server" (fresh store + persisted cache
/// dir) performs zero builds at all.
#[test]
fn model_reload_and_restart_build_nothing() {
    let dir = temp_dir("model_restart");
    let mut rng = Rng::new(107);
    let params = random_params(4, &mut rng);
    let codes = Tensor4::random_activations(Shape4::new(4, 16, 16, 1), 4, &mut rng);

    // First boot: two conv layers -> two dense-table builds plus two
    // absorbed-requantize tables for the fused chains.
    let store = Arc::new(TableStore::new());
    let m1 = QuantCnn::with_store(params.clone(), EngineChoice::Pcilt, &store);
    let reference = m1.forward(&codes);
    assert_eq!(store.stats().builds, 4);
    // Same model loaded again in-process: zero new builds.
    let m2 = QuantCnn::with_store(params.clone(), EngineChoice::Pcilt, &store);
    assert_eq!(store.stats().builds, 4, "reload must not rebuild");
    assert_eq!(m2.forward(&codes), reference);
    store.save(&dir).unwrap();

    // Restart: new process (fresh store), warmed from the cache dir —
    // requant artifacts persist and reload alongside the dense tables.
    let restarted = Arc::new(TableStore::new());
    restarted.load(&dir).unwrap();
    let m3 = QuantCnn::with_store(params, EngineChoice::Pcilt, &restarted);
    let s = restarted.stats();
    assert_eq!(s.builds, 0, "restarted server must perform zero table builds");
    assert_eq!(s.hits, 4);
    assert_eq!(m3.forward(&codes), reference, "cache-served inference must be bit-identical");

    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite: the planner charges post-dedup (marginal) bytes/builds from
/// store stats, so a repeated-weight network is no longer mis-scored away
/// from PCILT once its tables are resident.
#[test]
fn planner_charges_marginal_cost_for_resident_tables() {
    let mut rng = Rng::new(109);
    let w = Tensor4::random_weights(Shape4::new(4, 3, 3, 1), 8, &mut rng);
    let spec = LayerSpec {
        geom: ConvGeometry::unit_stride(3, 3),
        in_ch: 1,
        out_ch: 4,
        act_bits: 4,
        weight_bits: 8,
        input: Shape4::new(1, 4, 4, 1),
    };
    let one_shot = PlannerPolicy {
        amortize_invocations: 1.0,
        ..PlannerPolicy::default()
    };
    let store = Arc::new(TableStore::new());
    let planner = EnginePlanner::with_store(one_shot, store.clone());
    // Cold: the one-shot build cost keeps DM ahead.
    assert_eq!(planner.plan_layer(&spec, Some(&w)).chosen, EngineId::Dm);
    // A first instance of the layer builds through the store...
    let first = planner.choose(&w, &spec);
    assert_eq!(first.name(), "dm", "cold choice builds the planned DM engine");
    EngineId::Pcilt.build_with_store(&w, &spec, &store).unwrap();
    // ...after which the identical layer prices PCILT at marginal cost.
    let warm = planner.plan_layer(&spec, Some(&w));
    assert_eq!(warm.chosen, EngineId::Pcilt);
    let c = warm.candidate(EngineId::Pcilt).unwrap();
    assert!(c.cached);
    assert_eq!(c.build_evals, 0, "resident tables cost no build evals");
}

/// Corrupt cache files are rejected wholesale (checksum) and never load
/// partial state.
#[test]
fn corrupt_cache_never_loads() {
    let dir = temp_dir("corrupt");
    let mut rng = Rng::new(113);
    let w = Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng);
    let store = TableStore::new();
    let geom = ConvGeometry::unit_stride(3, 3);
    let _e = PciltEngine::from_store(&store, &w, 2, geom, &ConvFunc::Mul);
    store.save(&dir).unwrap();
    let bin = dir.join("tables.bin");
    let mut raw = std::fs::read(&bin).unwrap();
    let mid = raw.len() / 2;
    raw[mid] ^= 0x5A;
    std::fs::write(&bin, &raw).unwrap();
    let fresh = TableStore::new();
    assert!(fresh.load(&dir).is_err());
    assert_eq!(fresh.stats().entries, 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Keys are pure content addresses: a clone of the weights hits, a one
/// weight-value flip misses.
#[test]
fn content_addressing_across_tensors() {
    let mut rng = Rng::new(127);
    let w = Tensor4::random_weights(Shape4::new(2, 3, 3, 2), 8, &mut rng);
    let same = w.clone();
    let mut flipped = w.clone();
    let v = flipped.get(1, 2, 2, 1);
    flipped.set(1, 2, 2, 1, v.wrapping_add(1));
    let f = ConvFunc::Mul;
    assert_eq!(TableKey::dense(&w, 4, &f), TableKey::dense(&same, 4, &f));
    assert_ne!(TableKey::dense(&w, 4, &f), TableKey::dense(&flipped, 4, &f));
}
