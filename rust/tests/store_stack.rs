//! TableStore integration tests: store-borrowed engines are bit-identical
//! to owning engines, persistence roundtrips exactly, eviction rebuilds
//! correctly under a tiny budget, and a model loaded twice (or a "server
//! restart" against a persisted cache dir) performs zero redundant table
//! builds — the PR's acceptance criteria, verified by store counters.

use std::path::PathBuf;
use std::sync::Arc;

use pcilt::model::{random_params, EngineChoice, QuantCnn};
use pcilt::pcilt::dm::conv_reference;
use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::planner::{EngineId, EnginePlanner, LayerSpec, PlannerPolicy};
use pcilt::pcilt::store::StoreIoError;
use pcilt::pcilt::{
    ChannelWidths, ConvFunc, MixedEngine, PciltEngine, RowSegmentEngine, SegmentEngine,
    SharedEngine, TableKey, TableStore,
};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::propcheck::forall;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("pcilt_store_stack_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Property: every store-borrowed table engine computes the same
/// convolution as its table-owning twin, bit for bit, across random
/// shapes and cardinalities — and the second borrow never rebuilds.
#[test]
fn store_borrowed_engines_match_owned_bit_for_bit() {
    forall("store == owned for every engine", 15, |g| {
        let mut rng = Rng::new(g.i64(0, i64::MAX / 2) as u64);
        let bits = *rng.choose(&[1u32, 2, 4]);
        let (kh, kw) = *rng.choose(&[(3usize, 3usize), (5, 5)]);
        let ic = rng.range_i64(1, 2) as usize;
        let oc = rng.range_i64(1, 3) as usize;
        let h = kh + rng.range_i64(0, 4) as usize;
        let wd = kw + rng.range_i64(0, 4) as usize;
        let x = Tensor4::random_activations(Shape4::new(2, h, wd, ic), bits, &mut rng);
        let w = Tensor4::random_weights(Shape4::new(oc, kh, kw, ic), 8, &mut rng);
        let geom = ConvGeometry::unit_stride(kh, kw);
        let expect = conv_reference(&x, &w, geom);
        let f = ConvFunc::Mul;

        let store = TableStore::new();
        let engines: Vec<(&str, Box<dyn ConvEngine>, Box<dyn ConvEngine>)> = vec![
            (
                "pcilt",
                Box::new(PciltEngine::new(&w, bits, geom)),
                Box::new(PciltEngine::from_store(&store, &w, bits, geom, &f)),
            ),
            (
                "shared",
                Box::new(SharedEngine::new(&w, bits, geom)),
                Box::new(SharedEngine::from_store(&store, &w, bits, geom, &f)),
            ),
            (
                "segment",
                Box::new(SegmentEngine::new(&w, bits, 2, geom)),
                Box::new(SegmentEngine::from_store(&store, &w, bits, 2, geom, &f)),
            ),
            (
                "segment-row",
                Box::new(RowSegmentEngine::new(&w, bits, 2, geom)),
                Box::new(RowSegmentEngine::from_store(&store, &w, bits, 2, geom, &f)),
            ),
            (
                "mixed",
                Box::new(MixedEngine::new(&w, ChannelWidths::uniform(ic, bits), geom)),
                Box::new(MixedEngine::from_store(
                    &store,
                    &w,
                    ChannelWidths::uniform(ic, bits),
                    bits,
                    geom,
                    &f,
                )),
            ),
        ];
        let builds_after_first = store.stats().builds;
        for (name, owned, borrowed) in &engines {
            assert_eq!(owned.conv(&x), expect, "{name} owned != reference");
            assert_eq!(borrowed.conv(&x), expect, "{name} borrowed != reference");
        }
        // Borrowing the same content again must be all hits, no builds.
        let again = PciltEngine::from_store(&store, &w, bits, geom, &f);
        assert_eq!(again.conv(&x), expect);
        assert_eq!(store.stats().builds, builds_after_first, "rebuild on second borrow");
    });
}

/// Persistence roundtrip: save -> load -> identical entries (checksum
/// verified), and every engine built from the loaded store is
/// bit-identical to one built fresh.
#[test]
fn persistence_roundtrip_is_bit_identical() {
    let dir = temp_dir("roundtrip");
    let mut rng = Rng::new(101);
    let x = Tensor4::random_activations(Shape4::new(2, 7, 7, 2), 2, &mut rng);
    let w = Tensor4::random_weights(Shape4::new(3, 3, 3, 2), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;

    let store = TableStore::new();
    let fresh_pcilt = PciltEngine::from_store(&store, &w, 2, geom, &f);
    let fresh_shared = SharedEngine::from_store(&store, &w, 2, geom, &f);
    let fresh_segment = SegmentEngine::from_store(&store, &w, 2, 4, geom, &f);
    let report = store.save(&dir).unwrap();
    assert_eq!(report.entries, 3);

    // "Server restart": a brand-new store warms from the cache dir.
    let restarted = TableStore::new();
    assert_eq!(restarted.load(&dir).unwrap(), 3);
    let loaded_pcilt = PciltEngine::from_store(&restarted, &w, 2, geom, &f);
    let loaded_shared = SharedEngine::from_store(&restarted, &w, 2, geom, &f);
    let loaded_segment = SegmentEngine::from_store(&restarted, &w, 2, 4, geom, &f);
    let stats = restarted.stats();
    assert_eq!(stats.builds, 0, "warm boot must perform zero table builds");
    assert_eq!(stats.loads, 3);
    assert_eq!(stats.hits, 3);

    assert_eq!(loaded_pcilt.conv(&x), fresh_pcilt.conv(&x));
    assert_eq!(loaded_shared.conv(&x), fresh_shared.conv(&x));
    assert_eq!(loaded_segment.conv(&x), fresh_segment.conv(&x));

    // Saving the restarted store reproduces the byte-identical cache.
    let dir2 = temp_dir("roundtrip2");
    let report2 = restarted.save(&dir2).unwrap();
    assert_eq!(report2.checksum, report.checksum, "cache must be deterministic");
    assert_eq!(report2.payload_bytes, report.payload_bytes);

    std::fs::remove_dir_all(&dir).ok();
    std::fs::remove_dir_all(&dir2).ok();
}

/// Eviction under a tiny budget: the store sheds LRU entries, stays
/// correct, and transparently rebuilds on the next request.
#[test]
fn eviction_then_rebuild_is_correct() {
    let mut rng = Rng::new(103);
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    let x = Tensor4::random_activations(Shape4::new(1, 6, 6, 1), 4, &mut rng);
    let ws: Vec<Tensor4<i8>> = (0..4)
        .map(|_| Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng))
        .collect();
    let expects: Vec<_> = ws.iter().map(|w| conv_reference(&x, w, geom)).collect();

    // Budget fits roughly one layer's tables: 2 oc * 9 pos * 16 card * 4 B
    // (plus mirror) ~= 2.3 KiB; give it 4 KiB.
    let store = TableStore::with_budget(4 * 1024);
    for round in 0..3 {
        for (w, expect) in ws.iter().zip(&expects) {
            // Engine dropped at the end of each iteration, so its entry is
            // evictable when the next build pushes past the budget.
            let e = PciltEngine::from_store(&store, w, 4, geom, &f);
            assert_eq!(e.conv(&x), *expect, "round {round}");
        }
    }
    let stats = store.stats();
    assert!(stats.evictions > 0, "tiny budget must evict: {stats:?}");
    assert!(
        stats.builds > 4,
        "evicted entries must rebuild on miss: {stats:?}"
    );
    // Derived views (channels-last mirrors) grow entries after insert;
    // re-applying the budget evicts back under it now that no engine
    // borrows anything.
    store.set_budget_bytes(4 * 1024);
    let stats = store.stats();
    assert!(
        stats.bytes <= 4.0 * 1024.0,
        "resident bytes {} over budget with nothing borrowed",
        stats.bytes
    );
}

/// The headline criterion: a model loaded twice performs zero redundant
/// table builds, and a "restarted server" (fresh store + persisted cache
/// dir) performs zero builds at all.
#[test]
fn model_reload_and_restart_build_nothing() {
    let dir = temp_dir("model_restart");
    let mut rng = Rng::new(107);
    let params = random_params(4, &mut rng);
    let codes = Tensor4::random_activations(Shape4::new(4, 16, 16, 1), 4, &mut rng);

    // First boot: two conv layers -> two dense-table builds plus two
    // absorbed-requantize tables for the fused chains.
    let store = Arc::new(TableStore::new());
    let m1 = QuantCnn::with_store(params.clone(), EngineChoice::Pcilt, &store);
    let reference = m1.forward(&codes);
    assert_eq!(store.stats().builds, 4);
    // Same model loaded again in-process: zero new builds.
    let m2 = QuantCnn::with_store(params.clone(), EngineChoice::Pcilt, &store);
    assert_eq!(store.stats().builds, 4, "reload must not rebuild");
    assert_eq!(m2.forward(&codes), reference);
    store.save(&dir).unwrap();

    // Restart: new process (fresh store), warmed from the cache dir —
    // requant artifacts persist and reload alongside the dense tables.
    let restarted = Arc::new(TableStore::new());
    restarted.load(&dir).unwrap();
    let m3 = QuantCnn::with_store(params, EngineChoice::Pcilt, &restarted);
    let s = restarted.stats();
    assert_eq!(s.builds, 0, "restarted server must perform zero table builds");
    assert_eq!(s.hits, 4);
    assert_eq!(m3.forward(&codes), reference, "cache-served inference must be bit-identical");

    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite: the planner charges post-dedup (marginal) bytes/builds from
/// store stats, so a repeated-weight network is no longer mis-scored away
/// from PCILT once its tables are resident.
#[test]
fn planner_charges_marginal_cost_for_resident_tables() {
    let mut rng = Rng::new(109);
    let w = Tensor4::random_weights(Shape4::new(4, 3, 3, 1), 8, &mut rng);
    let spec = LayerSpec {
        geom: ConvGeometry::unit_stride(3, 3),
        in_ch: 1,
        out_ch: 4,
        act_bits: 4,
        weight_bits: 8,
        input: Shape4::new(1, 4, 4, 1),
    };
    let one_shot = PlannerPolicy {
        amortize_invocations: 1.0,
        ..PlannerPolicy::default()
    };
    let store = Arc::new(TableStore::new());
    let planner = EnginePlanner::with_store(one_shot, store.clone());
    // Cold: the one-shot build cost keeps DM ahead.
    assert_eq!(planner.plan_layer(&spec, Some(&w)).chosen, EngineId::Dm);
    // A first instance of the layer builds through the store...
    let first = planner.choose(&w, &spec);
    assert_eq!(first.name(), "dm", "cold choice builds the planned DM engine");
    EngineId::Pcilt.build_with_store(&w, &spec, &store).unwrap();
    // ...after which the identical layer prices PCILT at marginal cost.
    let warm = planner.plan_layer(&spec, Some(&w));
    assert_eq!(warm.chosen, EngineId::Pcilt);
    let c = warm.candidate(EngineId::Pcilt).unwrap();
    assert!(c.cached);
    assert_eq!(c.build_evals, 0, "resident tables cost no build evals");
}

/// Corrupt cache files are rejected wholesale (checksum) and never load
/// partial state.
#[test]
fn corrupt_cache_never_loads() {
    let dir = temp_dir("corrupt");
    let mut rng = Rng::new(113);
    let w = Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng);
    let store = TableStore::new();
    let geom = ConvGeometry::unit_stride(3, 3);
    let _e = PciltEngine::from_store(&store, &w, 2, geom, &ConvFunc::Mul);
    store.save(&dir).unwrap();
    let bin = dir.join("tables.bin");
    let mut raw = std::fs::read(&bin).unwrap();
    let mid = raw.len() / 2;
    raw[mid] ^= 0x5A;
    std::fs::write(&bin, &raw).unwrap();
    let fresh = TableStore::new();
    assert!(fresh.load(&dir).is_err());
    assert_eq!(fresh.stats().entries, 0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Tiering roundtrip across the whole lookup family: every idle entry is
/// demoted to the cold tier (`tables.bin`), pages back in on the next
/// borrow, and the gathers stay bit-identical — with zero rebuilds.
#[test]
fn demote_then_page_in_is_bit_identical_across_engines() {
    let dir = temp_dir("tiering");
    let mut rng = Rng::new(131);
    let bits = 4u32;
    let ic = 2usize;
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    let x = Tensor4::random_activations(Shape4::new(1, 7, 7, ic), bits, &mut rng);
    // Ternary weights: the dense/shared artifacts take the packed
    // representation, so the roundtrip shuttles both packed and flat
    // bodies through the cold tier.
    let w = Tensor4::from_fn(Shape4::new(4, 3, 3, ic), |_, _, _, _| *rng.choose(&[-1i8, 0, 1]));
    let make = |store: &TableStore| -> Vec<(&'static str, Box<dyn ConvEngine>)> {
        vec![
            ("pcilt", Box::new(PciltEngine::from_store(store, &w, bits, geom, &f))),
            ("shared", Box::new(SharedEngine::from_store(store, &w, bits, geom, &f))),
            ("segment", Box::new(SegmentEngine::from_store(store, &w, bits, 2, geom, &f))),
            ("segment-row", Box::new(RowSegmentEngine::from_store(store, &w, bits, 2, geom, &f))),
            (
                "mixed",
                Box::new(MixedEngine::from_store(
                    store,
                    &w,
                    ChannelWidths::uniform(ic, bits),
                    bits,
                    geom,
                    &f,
                )),
            ),
        ]
    };

    let store = TableStore::new();
    let engines = make(&store);
    let expects: Vec<_> = engines.iter().map(|(_, e)| e.conv(&x)).collect();
    store.save(&dir).unwrap();
    drop(engines);
    let builds = store.stats().builds;
    assert_eq!(builds, 5);

    // Demote: a 1-byte budget evicts every idle entry, and because the
    // saved cache covers them all, each eviction is a demotion (pageable)
    // rather than a loss.
    store.set_budget_bytes(1);
    let s = store.stats();
    assert_eq!(s.entries, 0, "nothing borrowed, so everything demotes");
    assert_eq!(s.demotions, 5, "saved entries must demote, not vanish: {s:?}");
    assert_eq!(s.cold_entries, 5);
    store.set_budget_bytes(0);

    // Page back in: the same borrows are served from the cold tier, with
    // zero new builds and bit-identical gathers.
    for ((name, e), expect) in make(&store).iter().zip(&expects) {
        assert_eq!(e.conv(&x), *expect, "{name} after page-in");
    }
    let s = store.stats();
    assert_eq!(s.builds, builds, "page-in must not rebuild: {s:?}");
    assert_eq!(s.page_ins, 5);
    assert_eq!(s.page_in_errors, 0);

    std::fs::remove_dir_all(&dir).ok();
}

/// The same roundtrip through a whole model: `QuantCnn`'s dense tables
/// *and* its absorbed-requantize tables demote and page back in with a
/// bit-identical forward pass.
#[test]
fn model_demote_then_page_in_covers_requant_tables() {
    let dir = temp_dir("tiering_model");
    let mut rng = Rng::new(137);
    let params = random_params(4, &mut rng);
    let codes = Tensor4::random_activations(Shape4::new(2, 16, 16, 1), 4, &mut rng);

    let store = Arc::new(TableStore::new());
    let m = QuantCnn::with_store(params.clone(), EngineChoice::Pcilt, &store);
    let reference = m.forward(&codes);
    store.save(&dir).unwrap();
    drop(m);
    let builds = store.stats().builds;
    assert_eq!(builds, 4, "2 dense + 2 requant tables");

    store.set_budget_bytes(1);
    assert_eq!(store.stats().entries, 0);
    store.set_budget_bytes(0);

    let m2 = QuantCnn::with_store(params, EngineChoice::Pcilt, &store);
    assert_eq!(m2.forward(&codes), reference, "paged-in model must be bit-identical");
    let s = store.stats();
    assert_eq!(s.builds, builds, "dense and requant tables page in, not rebuild: {s:?}");
    assert_eq!(s.page_ins, 4);
    assert_eq!(s.demotions, 4);

    std::fs::remove_dir_all(&dir).ok();
}

/// A damaged cold-tier body degrades to rebuild-from-weights: the per-body
/// checksum rejects it at page-in, the entry leaves the cold index, the
/// builder runs instead, and the result is still bit-identical.
#[test]
fn corrupt_cold_body_falls_back_to_rebuild() {
    let dir = temp_dir("cold_corrupt");
    let mut rng = Rng::new(139);
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    let bits = 4u32;
    let x = Tensor4::random_activations(Shape4::new(1, 6, 6, 1), bits, &mut rng);
    let w = Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng);
    let key = TableKey::dense(&w, bits, &f);

    let store = TableStore::new();
    let expect = {
        let e = PciltEngine::from_store(&store, &w, bits, geom, &f);
        e.conv(&x)
    };
    store.save(&dir).unwrap();

    // Flip the last byte on disk — inside the (single) entry's body, so
    // the manifest-level load checks are not what catches it.
    let bin = dir.join("tables.bin");
    let mut raw = std::fs::read(&bin).unwrap();
    let last = raw.len() - 1;
    raw[last] ^= 0xFF;
    std::fs::write(&bin, &raw).unwrap();

    store.set_budget_bytes(1);
    assert_eq!(store.stats().entries, 0);
    store.set_budget_bytes(0);
    let builds = store.stats().builds;

    let e = PciltEngine::from_store(&store, &w, bits, geom, &f);
    assert_eq!(e.conv(&x), expect, "rebuild fallback must stay bit-identical");
    let s = store.stats();
    assert_eq!(s.page_in_errors, 1, "damaged body must count a page-in error: {s:?}");
    assert_eq!(s.builds, builds + 1, "fallback rebuilds from weights");
    assert!(!store.cold_contains(key), "damaged cold entry must leave the index (no retry loop)");

    std::fs::remove_dir_all(&dir).ok();
}

/// A truncated cold file is rejected wholesale at attach time (manifest
/// payload length), before anything could page in from it.
#[test]
fn truncated_cold_file_is_rejected_on_attach() {
    let dir = temp_dir("cold_truncated");
    let mut rng = Rng::new(149);
    let w = Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(3, 3);
    let store = TableStore::new();
    let _e = PciltEngine::from_store(&store, &w, 2, geom, &ConvFunc::Mul);
    store.save(&dir).unwrap();

    let bin = dir.join("tables.bin");
    let mut raw = std::fs::read(&bin).unwrap();
    raw.truncate(raw.len() / 2);
    std::fs::write(&bin, &raw).unwrap();

    let fresh = TableStore::new();
    match fresh.attach_cold(&dir) {
        Err(StoreIoError::Corrupt(_)) => {}
        other => panic!("truncated cache must be rejected as corrupt, got {other:?}"),
    }
    assert_eq!(fresh.stats().cold_entries, 0, "rejected cache must index nothing");

    std::fs::remove_dir_all(&dir).ok();
}

/// `attach_cold` indexes a persisted cache without loading anything;
/// `promote_hot` then pages entries in ahead of demand, and every later
/// borrow is served from memory or the cold tier — never a rebuild.
#[test]
fn attach_cold_then_promote_serves_without_builds() {
    let dir = temp_dir("promote");
    let mut rng = Rng::new(151);
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    let bits = 2u32;
    let x = Tensor4::random_activations(Shape4::new(1, 6, 6, 1), bits, &mut rng);
    let ws: Vec<Tensor4<i8>> = (0..3)
        .map(|_| Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng))
        .collect();

    let seed_store = TableStore::new();
    for w in &ws {
        let _e = PciltEngine::from_store(&seed_store, w, bits, geom, &f);
    }
    seed_store.save(&dir).unwrap();

    let store = TableStore::new();
    assert_eq!(store.attach_cold(&dir).unwrap(), 3);
    let s = store.stats();
    assert_eq!(s.entries, 0, "attach must not load anything resident");
    assert_eq!(s.cold_entries, 3);

    assert_eq!(store.promote_hot(2), 2);
    let s = store.stats();
    assert_eq!(s.entries, 2);
    assert_eq!(s.page_ins, 2);
    assert_eq!(s.cold_entries, 1, "promoted entries leave the cold count");

    for w in &ws {
        let e = PciltEngine::from_store(&store, w, bits, geom, &f);
        let _ = e.conv(&x);
    }
    let s = store.stats();
    assert_eq!(s.builds, 0, "cold-attached boot must never rebuild: {s:?}");
    assert_eq!(s.page_ins, 3, "the one unpromoted entry pages in on demand");

    std::fs::remove_dir_all(&dir).ok();
}

/// Regression: an entry with a live borrow is never evicted, demoted or
/// shed, no matter how much churn pushes the store past its budget — the
/// holding engine keeps gathering bit-identically throughout.
#[test]
fn borrowed_entry_is_never_demoted_under_churn() {
    let mut rng = Rng::new(157);
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    let bits = 4u32;
    let x = Tensor4::random_activations(Shape4::new(1, 6, 6, 1), bits, &mut rng);
    let w_held = Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng);

    // Budget fits roughly one layer's tables (see eviction test above),
    // so every churn build pushes the store over budget while the first
    // engine still borrows its entry.
    let store = TableStore::with_budget(4 * 1024);
    let held = PciltEngine::from_store(&store, &w_held, bits, geom, &f);
    let expect = held.conv(&x);
    let key = TableKey::dense(&w_held, bits, &f);
    let resident = store.resident_bytes(key).expect("held entry must be resident");

    for i in 0..6 {
        let w = Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng);
        let e = PciltEngine::from_store(&store, &w, bits, geom, &f);
        let _ = e.conv(&x);
        assert!(store.contains(key), "round {i}: borrowed entry was evicted");
    }
    let s = store.stats();
    assert!(s.evictions > 0, "churn past the budget must evict idle entries: {s:?}");
    assert_eq!(
        store.resident_bytes(key),
        Some(resident),
        "borrowed entry must keep its views (no shed) while held"
    );
    assert_eq!(held.conv(&x), expect, "held engine must gather bit-identically after churn");
}

/// Budget eviction charges what an entry actually costs resident: packed
/// entries are charged their packed bytes, not their logical (flat) size.
/// The budget here is far below the models' combined flat footprint and
/// comfortably above their packed one — everything must stay resident.
#[test]
fn eviction_charges_packed_not_logical_bytes() {
    let dir = temp_dir("packed_accounting");
    const MODELS: usize = 4;
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    let bits = 8u32;

    let builder = TableStore::with_budget(0);
    builder.set_pack(true);
    for i in 0..MODELS {
        let mut r = Rng::new(2000 + i as u64);
        let shape = Shape4::new(8, 3, 3, 4);
        let w = Tensor4::from_fn(shape, |_, _, _, _| *r.choose(&[-1i8, 0, 1]));
        let _e = PciltEngine::from_store(&builder, &w, bits, geom, &f);
    }
    let s = builder.stats();
    assert_eq!(s.packed_entries as usize, MODELS, "ternary tables must pack");
    assert!(
        s.packed_bytes * 2.0 < s.packed_logical_bytes,
        "test needs a real compression gap: {s:?}"
    );
    builder.save(&dir).unwrap();

    // Budget between the packed and flat totals: a store charging logical
    // bytes would evict most entries, one charging packed bytes keeps all.
    let budget = (s.packed_logical_bytes / 2.0) as u64;
    let store = TableStore::with_budget(budget);
    store.set_pack(true);
    assert_eq!(store.load(&dir).unwrap(), MODELS);
    let t = store.stats();
    assert_eq!(t.entries as usize, MODELS, "packed residency must fit the budget: {t:?}");
    assert!(t.bytes <= budget as f64, "resident bytes over budget: {t:?}");

    std::fs::remove_dir_all(&dir).ok();
}

/// The per-model fairness cap only ever evicts tables owned exclusively
/// by over-budget models: a noisy tenant shrinks to its cap, the
/// within-budget tenant's tables survive untouched.
#[test]
fn per_model_budget_evicts_only_the_over_budget_owner() {
    let mut rng = Rng::new(163);
    let geom = ConvGeometry::unit_stride(3, 3);
    let f = ConvFunc::Mul;
    let bits = 4u32;
    let store = TableStore::new();
    store.set_pack(false); // deterministic flat sizes for the arithmetic below

    let ws: Vec<Tensor4<i8>> = (0..4)
        .map(|_| Tensor4::random_weights(Shape4::new(2, 3, 3, 1), 8, &mut rng))
        .collect();
    let keys: Vec<TableKey> = ws.iter().map(|w| TableKey::dense(w, bits, &f)).collect();
    for w in &ws {
        let _e = PciltEngine::from_store(&store, w, bits, geom, &f);
    }
    // "hog" owns the first three tables, "tenant" the last. All four are
    // the same shape, so they charge identical bytes.
    store.register_model_keys("hog", &keys[..3]);
    store.register_model_keys("tenant", &keys[3..]);
    let per_table = store.resident_bytes(keys[0]).unwrap();

    // Cap at 1.5 tables: "hog" (3 tables) is over, "tenant" (1) is not.
    let budget = (per_table * 1.5) as u64;
    store.set_model_budget_bytes(budget);
    let s = store.stats();
    assert_eq!(s.entries, 2, "hog must shrink to one table: {s:?}");
    assert!(store.contains(keys[3]), "tenant's table must survive hog's overrun");
    assert!(store.contains(keys[2]), "hog keeps its most recently used table");
    assert!(!store.contains(keys[0]) && !store.contains(keys[1]), "hog's LRU tables evict");
    for (model, bytes) in store.model_usage() {
        assert!(
            bytes <= budget as f64,
            "{model} still over its cap after enforcement ({bytes} > {budget})"
        );
    }
}

/// Keys are pure content addresses: a clone of the weights hits, a one
/// weight-value flip misses.
#[test]
fn content_addressing_across_tensors() {
    let mut rng = Rng::new(127);
    let w = Tensor4::random_weights(Shape4::new(2, 3, 3, 2), 8, &mut rng);
    let same = w.clone();
    let mut flipped = w.clone();
    let v = flipped.get(1, 2, 2, 1);
    flipped.set(1, 2, 2, 1, v.wrapping_add(1));
    let f = ConvFunc::Mul;
    assert_eq!(TableKey::dense(&w, 4, &f), TableKey::dense(&same, 4, &f));
    assert_ne!(TableKey::dense(&w, 4, &f), TableKey::dense(&flipped, 4, &f));
}
