"""L1 Pallas kernel: segment-offset PCILT conv (Figs 5-6).

The pre-processing ("bit shifting and masking") packs seg_n activation
codes into one offset inside the kernel — on TPU these are cheap VPU ops,
mirroring the paper's "separate circuitry ... pipelining the results to
the convolutional circuitry". One gather then retrieves the whole
segment's contribution.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segment_kernel(x_ref, tables_ref, o_ref, *, kh, kw, cin, cout, seg_n, act_bits):
    """x_ref: [1,H,W,Cin] uint8; tables_ref: [Cout,S,R] int32;
    o_ref: [1,OH,OW,Cout] int32."""
    x = x_ref[...].astype(jnp.int32)
    tables = tables_ref[...]
    _, h, w, _ = x.shape
    oh = h - kh + 1
    ow = w - kw + 1
    # im2col in the (ky, kx, ic) walk order.
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            cols.append(x[:, ky : ky + oh, kx : kx + ow, :])
    rf = jnp.concatenate(cols, axis=-1)  # [1,OH,OW,P]
    p = rf.shape[-1]
    n_seg = -(-p // seg_n)
    pad = n_seg * seg_n - p
    if pad:
        rf = jnp.pad(rf, ((0, 0), (0, 0), (0, 0), (0, pad)))
    grouped = rf.reshape(1, oh, ow, n_seg, seg_n)
    # offset packing: shift+mask only.
    shifts = jnp.arange(seg_n, dtype=jnp.int32) * act_bits
    offs = jnp.sum(grouped << shifts, axis=-1)  # [1,OH,OW,S]
    acc = jnp.zeros((1, oh, ow, cout), jnp.int32)
    for s in range(n_seg):
        t = tables[:, s, :]  # [Cout, R]
        gathered = jnp.take(t, offs[..., s], axis=1)  # [Cout,1,OH,OW]
        acc = acc + jnp.moveaxis(gathered, 0, -1)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("kh", "kw", "seg_n", "act_bits"))
def segment_conv(x, seg_tables, kh, kw, seg_n, act_bits):
    """Segment-offset convolution via a Pallas kernel."""
    n, h, w, cin = x.shape
    cout, n_seg, r = seg_tables.shape
    assert n_seg == -(-(kh * kw * cin) // seg_n)
    assert r == 1 << (seg_n * act_bits)
    oh, ow = h - kh + 1, w - kw + 1
    kernel = functools.partial(
        _segment_kernel, kh=kh, kw=kw, cin=cin, cout=cout, seg_n=seg_n, act_bits=act_bits
    )
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((cout, n_seg, r), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.int32),
        interpret=True,
    )(x, seg_tables)
