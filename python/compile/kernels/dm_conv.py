"""L1 Pallas kernel: direct-multiplication conv baseline.

The comparator for the PCILT kernel: same tiling and grid, but the inner
loop multiplies weight x activation (what an MXU/MAC datapath would do)
instead of gathering from tables. Used by E1's kernel-level comparison and
as the DM variant of the AOT model artifact.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dm_kernel(x_ref, w_ref, o_ref, *, kh, kw):
    """x_ref: [1,H,W,Cin] uint8; w_ref: [Cout,KH,KW,Cin] int8;
    o_ref: [1,OH,OW,Cout] int32."""
    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    _, h, wd, _ = x.shape
    cout = w.shape[0]
    oh = h - kh + 1
    ow = wd - kw + 1
    acc = jnp.zeros((1, oh, ow, cout), jnp.int32)
    for ky in range(kh):
        for kx in range(kw):
            patch = x[:, ky : ky + oh, kx : kx + ow, :]  # [1,OH,OW,Cin]
            acc = acc + jax.lax.dot_general(
                patch,
                w[:, ky, kx, :],
                dimension_numbers=(((3,), (1,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("kh", "kw"))
def dm_conv(x, w, kh, kw):
    """DM convolution via a Pallas kernel (unit stride, valid padding)."""
    n, h, wd, cin = x.shape
    cout, wkh, wkw, wcin = w.shape
    assert (wkh, wkw, wcin) == (kh, kw, cin)
    oh, ow = h - kh + 1, wd - kw + 1
    kernel = functools.partial(_dm_kernel, kh=kh, kw=kw)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, wd, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((cout, kh, kw, cin), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.int32),
        interpret=True,
    )(x, w)
