"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Everything here is deliberately the most literal possible formulation of
the paper's math — the Pallas kernels and the rust engines are both tested
against these functions. Integer semantics (i32 accumulators, exact table
products) mirror `rust/src/pcilt/` bit for bit.

Layouts match the rust side: activations NHWC uint8 codes, weights OHWI
int8, outputs NHWC int32.
"""

import jax.numpy as jnp


def conv2d_dm(x, w, stride=(1, 1)):
    """Direct-multiplication valid convolution (correlation).

    x: [N, H, W, Cin] integer codes (any int dtype, values >= 0)
    w: [Cout, KH, KW, Cin] signed integer weights
    returns [N, OH, OW, Cout] int32
    """
    x = x.astype(jnp.int32)
    w = w.astype(jnp.int32)
    n, h, wd, cin = x.shape
    cout, kh, kw, wcin = w.shape
    assert cin == wcin, f"cin {cin} != weight cin {wcin}"
    sy, sx = stride
    oh = (h - kh) // sy + 1
    ow = (wd - kw) // sx + 1
    out = jnp.zeros((n, oh, ow, cout), jnp.int32)
    for ky in range(kh):
        for kx in range(kw):
            patch = x[:, ky : ky + oh * sy : sy, kx : kx + ow * sx : sx, :]
            # [N,OH,OW,Cin] x [Cout,Cin] -> [N,OH,OW,Cout]
            out = out + jnp.einsum("nhwc,oc->nhwo", patch, w[:, ky, kx, :])
    return out


def build_tables(w, act_bits):
    """PCILT construction (Fig 1): tables[oc, p, a] = w[oc, p] * a.

    w: [Cout, KH, KW, Cin] -> tables [Cout, KH*KW*Cin, 2**act_bits] int32.
    Position order (ky, kx, ic) row-major, matching rust LayerTables.
    """
    cout = w.shape[0]
    flat = w.reshape(cout, -1).astype(jnp.int32)  # [Cout, P]
    acts = jnp.arange(2**act_bits, dtype=jnp.int32)  # [A]
    return flat[:, :, None] * acts[None, None, :]


def conv2d_pcilt(x, tables, kh, kw, stride=(1, 1)):
    """PCILT convolution (Fig 2): gather products from tables and add.

    x: [N, H, W, Cin] uint8 codes < 2**act_bits
    tables: [Cout, P, A] with P = KH*KW*Cin
    """
    n, h, wd, cin = x.shape
    cout, p, _a = tables.shape
    assert p == kh * kw * cin
    sy, sx = stride
    oh = (h - kh) // sy + 1
    ow = (wd - kw) // sx + 1
    out = jnp.zeros((n, oh, ow, cout), jnp.int32)
    pos = 0
    for ky in range(kh):
        for kx in range(kw):
            patch = x[:, ky : ky + oh * sy : sy, kx : kx + ow * sx : sx, :].astype(jnp.int32)
            # gather tables[oc, pos+ic, patch] summed over ic
            for ic in range(cin):
                t = tables[:, pos + ic, :]  # [Cout, A]
                out = out + t[:, patch[..., ic]].transpose(1, 2, 3, 0)
            pos += cin
    return out


def im2col_rf(x, kh, kw, stride=(1, 1)):
    """Unfold RFs in the rust walk order (ky, kx, ic): [N,OH,OW,KH*KW*Cin]."""
    n, h, wd, cin = x.shape
    sy, sx = stride
    oh = (h - kh) // sy + 1
    ow = (wd - kw) // sx + 1
    cols = []
    for ky in range(kh):
        for kx in range(kw):
            cols.append(x[:, ky : ky + oh * sy : sy, kx : kx + ow * sx : sx, :])
    return jnp.concatenate(cols, axis=-1)


def pack_offsets(rf_codes, seg_n, act_bits):
    """Pack flattened RF codes into segment offsets (Fig 5 pre-processing).

    rf_codes: [..., P] integer codes; P padded to multiple of seg_n with 0.
    returns [..., ceil(P/seg_n)] int32 offsets (little-endian packing).
    """
    p = rf_codes.shape[-1]
    n_seg = -(-p // seg_n)
    pad = n_seg * seg_n - p
    if pad:
        rf_codes = jnp.pad(rf_codes, [(0, 0)] * (rf_codes.ndim - 1) + [(0, pad)])
    grouped = rf_codes.reshape(rf_codes.shape[:-1] + (n_seg, seg_n)).astype(jnp.int32)
    shifts = jnp.arange(seg_n, dtype=jnp.int32) * act_bits
    return jnp.sum(grouped << shifts, axis=-1)


def build_segment_tables(w, act_bits, seg_n):
    """Segment PCILTs (Fig 5): table[oc, s, off] = sum_j w_j * a_j(off)."""
    cout = w.shape[0]
    flat = w.reshape(cout, -1).astype(jnp.int32)  # [Cout, P]
    p = flat.shape[1]
    n_seg = -(-p // seg_n)
    pad = n_seg * seg_n - p
    if pad:
        flat = jnp.pad(flat, [(0, 0), (0, pad)])
    seg_w = flat.reshape(cout, n_seg, seg_n)  # [Cout, S, seg_n]
    offs = jnp.arange(2 ** (seg_n * act_bits), dtype=jnp.int32)  # [R]
    mask = (1 << act_bits) - 1
    # decode a_j for every offset: [R, seg_n]
    a = (offs[:, None] >> (jnp.arange(seg_n, dtype=jnp.int32) * act_bits)[None, :]) & mask
    # [Cout, S, R]
    return jnp.einsum("csj,rj->csr", seg_w, a)


def conv2d_segment(x, seg_tables, kh, kw, seg_n, act_bits, stride=(1, 1)):
    """Segment-offset convolution (Fig 6)."""
    rf = im2col_rf(x, kh, kw, stride).astype(jnp.int32)
    offs = pack_offsets(rf, seg_n, act_bits)  # [N,OH,OW,S]
    cout, n_seg, _r = seg_tables.shape
    out = jnp.zeros(offs.shape[:3] + (cout,), jnp.int32)
    for s in range(n_seg):
        t = seg_tables[:, s, :]  # [Cout, R]
        out = out + t[:, offs[..., s]].transpose(1, 2, 3, 0)
    return out


def quantize_unsigned(x, max_val, bits):
    """Unsigned activation quantizer, mirrors rust `Quantizer::unsigned`."""
    qmax = (1 << bits) - 1
    scale = jnp.where(max_val > 0, max_val / qmax, 1.0)
    q = jnp.clip(jnp.round(x / scale), 0, qmax)
    return q.astype(jnp.uint8), scale


def quantize_symmetric(w, bits):
    """Symmetric weight quantizer, mirrors rust `Quantizer::symmetric`."""
    qmax = (1 << (bits - 1)) - 1
    max_abs = jnp.max(jnp.abs(w))
    scale = jnp.where(max_abs > 0, max_abs / qmax, 1.0)
    q = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    return q.astype(jnp.int8), scale
