"""L1 Pallas kernel: PCILT gather-convolution.

The paper's Fig 2/3 datapath, rethought for TPU (DESIGN.md §Hardware-
Adaptation): the PCILT bank for a whole layer is small enough to sit
**resident in VMEM** (a 4-bit activation domain is 16 entries per weight;
even a 5x5x64 filter bank is ~400 KB at int32, and the configs used here
are well under the ~16 MB VMEM budget), so the grid streams activation
tiles HBM->VMEM while every grid step reuses the same table block. The
multiplier-free inner loop is a VPU gather (activation code indexes the
table row) followed by the Fig 4 adder tree, which on TPU is the VPU's
tree reduction over the position axis.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated through the interpret path and the
same HLO is what the rust runtime executes (see aot.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pcilt_kernel(x_ref, tables_ref, o_ref, *, kh, kw, cin, cout):
    """One batch-row grid step.

    x_ref:      [1, H, W, Cin]  uint8 activation codes (VMEM tile)
    tables_ref: [Cout, P, A]    int32 PCILT bank (whole, VMEM-resident)
    o_ref:      [1, OH, OW, Cout] int32
    """
    x = x_ref[...].astype(jnp.int32)
    tables = tables_ref[...]
    _, h, w, _ = x.shape
    oh = h - kh + 1
    ow = w - kw + 1
    acc = jnp.zeros((1, oh, ow, cout), jnp.int32)
    pos = 0
    for ky in range(kh):
        for kx in range(kw):
            patch = x[:, ky : ky + oh, kx : kx + ow, :]  # [1,OH,OW,Cin]
            for ic in range(cin):
                # The PCILT fetch: activation value *is* the table offset.
                t = tables[:, pos + ic, :]  # [Cout, A]
                gathered = jnp.take(t, patch[..., ic], axis=1)  # [Cout,1,OH,OW]
                acc = acc + jnp.moveaxis(gathered, 0, -1)
            pos += cin
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("kh", "kw"))
def pcilt_conv(x, tables, kh, kw):
    """PCILT convolution via a Pallas kernel (unit stride, valid padding).

    x: [N, H, W, Cin] uint8; tables: [Cout, P, A] int32 (P = kh*kw*Cin).
    Grid over the batch: each step owns one sample; the table bank is
    mapped whole into every step (block index 0), i.e. VMEM-resident.
    """
    n, h, w, cin = x.shape
    cout, p, a = tables.shape
    assert p == kh * kw * cin, f"tables P={p} != {kh}*{kw}*{cin}"
    oh, ow = h - kh + 1, w - kw + 1
    kernel = functools.partial(_pcilt_kernel, kh=kh, kw=kw, cin=cin, cout=cout)
    return pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, h, w, cin), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((cout, p, a), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, oh, ow, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.int32),
        interpret=True,
    )(x, tables)


def vmem_footprint_bytes(h, w, cin, cout, kh, kw, act_bits):
    """Analytic VMEM footprint of one grid step (perf model, DESIGN.md §Perf):
    activation tile + table bank + output tile, in bytes."""
    act = h * w * cin  # uint8
    tables = cout * kh * kw * cin * (1 << act_bits) * 4
    out = (h - kh + 1) * (w - kw + 1) * cout * 4
    return act + tables + out
