"""Synthetic 8-class 16x16 glyph corpus.

The paper trains/evaluates on unspecified data; per DESIGN.md §2 we use a
procedural corpus so the repo is self-contained: eight structured glyph
classes with random jitter, per-pixel noise and amplitude scaling. Hard
enough that an untrained net is at 12.5% and a trained quantized CNN
reaches >90%, which is all the quantization-accuracy experiment (E10)
needs.
"""

import numpy as np

IMG = 16
NUM_CLASSES = 8


def _glyph(cls, rng):
    """Draw one clean glyph of class `cls` on a 16x16 canvas."""
    img = np.zeros((IMG, IMG), np.float32)
    c = IMG // 2
    if cls == 0:  # horizontal bar
        r = rng.integers(4, IMG - 4)
        img[r - 1 : r + 1, 2:-2] = 1.0
    elif cls == 1:  # vertical bar
        r = rng.integers(4, IMG - 4)
        img[2:-2, r - 1 : r + 1] = 1.0
    elif cls == 2:  # main diagonal
        for i in range(2, IMG - 2):
            img[i, max(0, i - 1) : i + 1] = 1.0
    elif cls == 3:  # cross
        img[c - 1 : c + 1, 2:-2] = 1.0
        img[2:-2, c - 1 : c + 1] = 1.0
    elif cls == 4:  # square outline
        a, b = 3, IMG - 3
        img[a:b, a] = img[a:b, b - 1] = 1.0
        img[a, a:b] = img[b - 1, a:b] = 1.0
    elif cls == 5:  # filled disc
        yy, xx = np.mgrid[0:IMG, 0:IMG]
        img[(yy - c) ** 2 + (xx - c) ** 2 <= 16] = 1.0
    elif cls == 6:  # checkerboard
        img[::4, :] = 0.0
        yy, xx = np.mgrid[0:IMG, 0:IMG]
        img[((yy // 2) + (xx // 2)) % 2 == 0] = 1.0
    elif cls == 7:  # T shape
        img[2:4, 2:-2] = 1.0
        img[2:-2, c - 1 : c + 1] = 1.0
    else:
        raise ValueError(cls)
    return img


def make_dataset(n, seed=0, noise=0.15):
    """Returns (x [n,16,16,1] float32 in [0,1], y [n] int32)."""
    rng = np.random.default_rng(seed)
    xs = np.zeros((n, IMG, IMG, 1), np.float32)
    ys = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    for i, cls in enumerate(ys):
        g = _glyph(int(cls), rng)
        # random shift by up to ±2 px
        dy, dx = rng.integers(-2, 3, size=2)
        g = np.roll(np.roll(g, dy, axis=0), dx, axis=1)
        # amplitude + additive noise, clipped to [0,1]
        amp = rng.uniform(0.6, 1.0)
        g = amp * g + rng.normal(0, noise, g.shape)
        xs[i, :, :, 0] = np.clip(g, 0.0, 1.0)
    return xs, ys
