"""L2: the QuantCNN model — training graph (fake-quant STE) and the
integer inference graph wired to the L1 Pallas kernels.

Architecture (deliberately small; the paper's setting is low-cardinality
inference, not large-scale training):

    input [B,16,16,1] float in [0,1]
      -> quantize to act codes (act_bits)
    conv1 3x3, 1->C1, int weights    -> requant+relu -> maxpool 2x2
    conv2 3x3, C1->C2, int weights   -> requant+relu -> maxpool 2x2
    flatten -> dense -> logits [B,8]

The integer path is EXACTLY mirrored by `rust/src/model/` (same quantizer
formulas, same round-ties-even requant), so PJRT artifact outputs and the
rust-native PCILT engine outputs are bit-comparable.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.dm_conv import dm_conv
from .kernels.pcilt_conv import pcilt_conv
from .kernels.segment_conv import segment_conv

NUM_CLASSES = 8
C1, C2 = 8, 16
K = 3


@dataclasses.dataclass
class ModelConfig:
    act_bits: int = 4
    weight_bits: int = 8
    # 'pcilt' | 'dm' | 'segment' — which L1 kernel the inference graph uses
    engine: str = "pcilt"
    seg_n: int = 2  # for engine == 'segment'


def init_params(rng_key, cfg: ModelConfig):
    """He-init float master weights."""
    k1, k2, k3 = jax.random.split(rng_key, 3)
    return {
        "w1": jax.random.normal(k1, (C1, K, K, 1)) * (2.0 / (K * K)) ** 0.5,
        "w2": jax.random.normal(k2, (C2, K, K, C1)) * (2.0 / (K * K * C1)) ** 0.5,
        "w3": jax.random.normal(k3, (NUM_CLASSES, 2 * 2 * C2)) * 0.1,
    }


# ---------------------------------------------------------------------------
# training graph (float with straight-through fake quantization)
# ---------------------------------------------------------------------------


def _ste_round(x):
    """Round with a straight-through gradient."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


def _fake_quant_act(x, max_val, bits):
    qmax = (1 << bits) - 1
    scale = max_val / qmax
    q = jnp.clip(_ste_round(x / scale), 0, qmax)
    return q * scale


def _fake_quant_weight(w, bits):
    qmax = (1 << (bits - 1)) - 1
    scale = jnp.maximum(jax.lax.stop_gradient(jnp.max(jnp.abs(w))), 1e-6) / qmax
    q = jnp.clip(_ste_round(w / scale), -qmax, qmax)
    return q * scale


def _conv_f32(x, w):
    """Float correlation, OHWI weights, valid padding (training path)."""
    return jax.lax.conv_general_dilated(
        x,
        jnp.transpose(w, (1, 2, 3, 0)),  # OHWI -> HWIO
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _pool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


# Fixed activation clip ranges (static, so train == infer calibration).
ACT1_MAX = 4.0
ACT2_MAX = 8.0


def forward_train(params, x, cfg: ModelConfig):
    """Fake-quantized float forward used for training."""
    a = _fake_quant_act(x, 1.0, cfg.act_bits)
    w1 = _fake_quant_weight(params["w1"], cfg.weight_bits)
    h = _conv_f32(a, w1)
    h = jnp.clip(h, 0.0, ACT1_MAX)
    h = _fake_quant_act(h, ACT1_MAX, cfg.act_bits)
    h = _pool(h)  # [B,7,7,C1]
    w2 = _fake_quant_weight(params["w2"], cfg.weight_bits)
    h = _conv_f32(h, w2)
    h = jnp.clip(h, 0.0, ACT2_MAX)
    h = _fake_quant_act(h, ACT2_MAX, cfg.act_bits)
    h = _pool(h)  # [B,2,2,C2]
    h = h.reshape(h.shape[0], -1)
    return h @ params["w3"].T


def loss_fn(params, x, y, cfg: ModelConfig):
    logits = forward_train(params, x, cfg)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


# ---------------------------------------------------------------------------
# integer inference graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuantizedModel:
    """Frozen integer parameters + scales for the inference graph."""

    cfg: ModelConfig
    w1: jnp.ndarray  # int8 [C1,K,K,1]
    w2: jnp.ndarray  # int8 [C2,K,K,C1]
    w3: jnp.ndarray  # int8 [8, 64]
    s_in: float
    s_w1: float
    s_w2: float
    s_w3: float
    s_a1: float  # scale of conv1 output codes
    s_a2: float


def quantize_model(params, cfg: ModelConfig) -> QuantizedModel:
    w1, s_w1 = ref.quantize_symmetric(params["w1"], cfg.weight_bits)
    w2, s_w2 = ref.quantize_symmetric(params["w2"], cfg.weight_bits)
    w3, s_w3 = ref.quantize_symmetric(params["w3"], cfg.weight_bits)
    qmax = (1 << cfg.act_bits) - 1
    return QuantizedModel(
        cfg=cfg,
        w1=w1,
        w2=w2,
        w3=w3,
        s_in=1.0 / qmax,
        s_w1=float(s_w1),
        s_w2=float(s_w2),
        s_w3=float(s_w3),
        s_a1=ACT1_MAX / qmax,
        s_a2=ACT2_MAX / qmax,
    )


def _requant(acc, multiplier, bits):
    """i32 accumulator -> unsigned act codes. Round-ties-even to match the
    rust implementation's `round_ties_even` exactly."""
    v = jnp.round(acc.astype(jnp.float32) * multiplier)
    return jnp.clip(v, 0, (1 << bits) - 1).astype(jnp.uint8)


def _pool_codes(x):
    return jax.lax.reduce_window(
        x, jnp.uint8(0), jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _conv_int(x_codes, w_int8, qm: QuantizedModel):
    """Dispatch to the configured L1 kernel."""
    cfg = qm.cfg
    kh = kw = K
    if cfg.engine == "dm":
        return dm_conv(x_codes, w_int8, kh, kw)
    if cfg.engine == "pcilt":
        tables = ref.build_tables(w_int8, cfg.act_bits)
        return pcilt_conv(x_codes, tables, kh, kw)
    if cfg.engine == "segment":
        st = ref.build_segment_tables(w_int8, cfg.act_bits, cfg.seg_n)
        return segment_conv(x_codes, st, kh, kw, cfg.seg_n, cfg.act_bits)
    raise ValueError(f"unknown engine {cfg.engine}")


def forward_int(qm: QuantizedModel, x_codes):
    """Integer inference: uint8 input codes -> int32 logits.

    All heavy compute goes through the L1 Pallas kernels; the only float
    ops are the requant multipliers (as on real int8 inference stacks).
    """
    cfg = qm.cfg
    m1 = qm.s_in * qm.s_w1 / qm.s_a1
    acc1 = _conv_int(x_codes, qm.w1, qm)  # [B,14,14,C1]
    a1 = _requant(acc1, m1, cfg.act_bits)  # relu folded into the clamp >= 0
    a1 = _pool_codes(a1)  # [B,7,7,C1]
    m2 = qm.s_a1 * qm.s_w2 / qm.s_a2
    acc2 = _conv_int(a1, qm.w2, qm)  # [B,5,5,C2]
    a2 = _requant(acc2, m2, cfg.act_bits)
    a2 = _pool_codes(a2)  # [B,2,2,C2]
    flat = a2.reshape(a2.shape[0], -1).astype(jnp.int32)  # [B,64]
    logits_i32 = flat @ qm.w3.astype(jnp.int32).T  # [B,8]
    return logits_i32


def forward_float_eval(params, x, cfg: ModelConfig):
    """Float (non-quantized) forward, the FP32 accuracy baseline of E10."""
    h = _conv_f32(x, params["w1"])
    h = jnp.clip(h, 0.0, ACT1_MAX)
    h = _pool(h)
    h = _conv_f32(h, params["w2"])
    h = jnp.clip(h, 0.0, ACT2_MAX)
    h = _pool(h)
    h = h.reshape(h.shape[0], -1)
    return h @ params["w3"].T


def encode_input(x_float, act_bits):
    """Float [0,1] images -> uint8 activation codes (the serving front
    door; rust mirrors this in `model::encode_input`)."""
    q, _ = ref.quantize_unsigned(x_float, 1.0, act_bits)
    return q
