"""L2 training: SGD with momentum on the fake-quantized QuantCNN, plus the
E10 cardinality sweep (FP32 vs INT8/4/2/bool activations).

Run directly for a training log, or let aot.py call `train()`.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .model import ModelConfig, forward_float_eval, forward_train, init_params, loss_fn


def accuracy(logits, y):
    return float(jnp.mean(jnp.argmax(logits, axis=1) == y))


def train(
    cfg: ModelConfig,
    steps=400,
    batch=64,
    lr=0.05,
    momentum=0.9,
    seed=0,
    train_n=4096,
    test_n=1024,
    log_every=50,
    verbose=True,
):
    """Train; returns (params, log) where log is a list of dict rows."""
    xs, ys = data.make_dataset(train_n, seed=seed)
    xt, yt = data.make_dataset(test_n, seed=seed + 1)
    xs, ys = jnp.asarray(xs), jnp.asarray(ys)
    xt, yt = jnp.asarray(xt), jnp.asarray(yt)

    params = init_params(jax.random.PRNGKey(seed), cfg)
    vel = jax.tree_util.tree_map(jnp.zeros_like, params)

    @jax.jit
    def step(params, vel, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, xb, yb, cfg)
        vel = jax.tree_util.tree_map(lambda v, g: momentum * v - lr * g, vel, grads)
        params = jax.tree_util.tree_map(lambda p, v: p + v, params, vel)
        return params, vel, loss

    rng = np.random.default_rng(seed)
    log = []
    t0 = time.time()
    for i in range(steps):
        idx = rng.integers(0, train_n, size=batch)
        params, vel, loss = step(params, vel, xs[idx], ys[idx])
        if i % log_every == 0 or i == steps - 1:
            test_acc = accuracy(forward_train(params, xt, cfg), yt)
            row = {
                "step": i,
                "loss": float(loss),
                "test_acc": test_acc,
                "elapsed_s": time.time() - t0,
            }
            log.append(row)
            if verbose:
                print(
                    f"step {i:4d}  loss {row['loss']:.4f}  "
                    f"test_acc {test_acc:.3f}  ({row['elapsed_s']:.1f}s)"
                )
    return params, log


def cardinality_sweep(steps=400, seed=0):
    """E10: accuracy at FP32 and act_bits in {8,4,2,1}. Returns rows."""
    rows = []
    # FP32 baseline: train unquantized (act_bits high enough to be ~lossless
    # in the STE graph is not the same as true fp32 — train a float model).
    cfg = ModelConfig(act_bits=8)
    params, _ = train(cfg, steps=steps, seed=seed, verbose=False)
    xt, yt = data.make_dataset(1024, seed=seed + 1)
    fp32_acc = accuracy(forward_float_eval(params, jnp.asarray(xt), cfg), jnp.asarray(yt))
    rows.append({"setting": "fp32", "test_acc": fp32_acc})
    for bits in (8, 4, 2, 1):
        cfg = ModelConfig(act_bits=bits)
        params, log = train(cfg, steps=steps, seed=seed, verbose=False)
        rows.append({"setting": f"int{bits}", "test_acc": log[-1]["test_acc"]})
    return rows


if __name__ == "__main__":
    cfg = ModelConfig()
    print(f"training QuantCNN act_bits={cfg.act_bits} weight_bits={cfg.weight_bits}")
    train(cfg)
