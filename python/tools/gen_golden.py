#!/usr/bin/env python3
"""Generate the golden conformance fixtures under rust/tests/data/.

Produces golden_<name>.bin files consumed by rust/tests/common/mod.rs —
an implementation of the integer inference pipeline *independent* of the
rust crate, so the conformance suite does not rest solely on the
in-process DM reference agreeing with itself.

The stage graphs MUST mirror `golden_spec` in rust/tests/common/mod.rs.
All requantize scales are dyadic rationals, exact in both float32 and
float64, so numpy and rust f32 denote identical values. Requantization is
float32 multiply + round-half-even (np.rint) + clamp, matching
`pcilt::fused::requant_code` bit for bit.

Binary layout (little-endian):
  magic "PGLD" | u32 version=1
  u32 n_convs | per conv: u32 o,h,w,i then o*h*w*i weight bytes (i8)
  u32 dense_len | dense weight bytes (i8)
  u32 b,h,w,c | input code bytes (u8)
  u32 rows, classes | rows*classes expected logits (i32)
"""

import struct
from pathlib import Path

import numpy as np

OUT_DIR = Path(__file__).resolve().parents[2] / "rust" / "tests" / "data"


def conv2d(x, w):
    """Valid conv, stride 1. x [B,H,W,C] int64, w [O,kh,kw,I] int64."""
    b, h, wd, c = x.shape
    o, kh, kw, ci = w.shape
    assert c == ci
    oh, ow = h - kh + 1, wd - kw + 1
    out = np.zeros((b, oh, ow, o), dtype=np.int64)
    for ky in range(kh):
        for kx in range(kw):
            patch = x[:, ky : ky + oh, kx : kx + ow, :]  # [B,oh,ow,C]
            # sum over C for every output channel
            out += np.einsum("bhwc,oc->bhwo", patch, w[:, ky, kx, :])
    return out


def requant(acc, scale, qmax):
    """float32 multiply + round-half-even + clamp, exactly as rust."""
    r = np.rint(acc.astype(np.float32) * np.float32(scale)).astype(np.int64)
    return np.clip(r, 0, qmax).astype(np.int64)


def max_pool(x, k):
    """k x k max pool, stride k, floor semantics (trailing dropped)."""
    b, h, w, c = x.shape
    ph, pw = h // k, w // k
    x = x[:, : ph * k, : pw * k, :]
    return x.reshape(b, ph, k, pw, k, c).max(axis=(2, 4))


def dense(x, w_mat):
    """Flatten NHWC row-major per sample, integer dot per class."""
    b = x.shape[0]
    flat = x.reshape(b, -1)  # row-major [H,W,C] flattening
    return flat @ w_mat.astype(np.int64).T  # [B, classes]


def run(spec, convs, dense_w, x):
    acc = None
    codes = x.astype(np.int64)
    qmax = (1 << spec["act_bits"]) - 1
    ci = 0
    for stage in spec["stages"]:
        kind = stage[0]
        if kind == "conv":
            acc = conv2d(codes, convs[ci].astype(np.int64))
            ci += 1
        elif kind == "requant":
            codes = requant(acc, stage[1], qmax)
        elif kind == "pool":
            codes = max_pool(codes, stage[1])
        elif kind == "dense":
            return dense(codes, dense_w)
    raise AssertionError("spec must end with dense")


# Stage graphs — keep in sync with rust/tests/common/mod.rs golden_spec().
SPECS = {
    "g2_pool_floor": {
        "act_bits": 2,
        "img": 12,
        "in_ch": 1,
        "batch": 3,
        "seed": 1021,
        "convs": [(4, 3, 3, 1), (6, 3, 3, 4)],
        "classes": 5,
        "features": 1 * 1 * 6,
        "stages": [
            ("conv",),
            ("requant", 0.0625),
            ("pool", 2),
            ("conv",),
            ("requant", 0.09375),
            ("pool", 2),  # 3x3 -> 1x1, floor
            ("dense",),
        ],
    },
    "g4_odd_maps": {
        "act_bits": 4,
        "img": 9,
        "in_ch": 2,
        "batch": 2,
        "seed": 1022,
        "convs": [(3, 3, 3, 2), (5, 3, 3, 3)],
        "classes": 4,
        "features": 5 * 5 * 5,
        "stages": [
            ("conv",),
            ("requant", 0.03125),
            ("conv",),
            ("requant", 0.046875),
            ("dense",),
        ],
    },
    "g8_deep_pool": {
        "act_bits": 8,
        "img": 10,
        "in_ch": 1,
        "batch": 2,
        "seed": 1023,
        "convs": [(2, 3, 3, 1), (3, 3, 3, 2)],
        "classes": 3,
        "features": 1 * 1 * 3,
        "stages": [
            ("conv",),
            ("requant", 0.00390625),
            ("pool", 2),
            ("conv",),
            ("requant", 0.015625),
            ("pool", 2),
            ("dense",),
        ],
    },
}


def emit(name, spec):
    rng = np.random.RandomState(spec["seed"])
    convs = [rng.randint(-127, 128, size=s).astype(np.int8) for s in spec["convs"]]
    dense_w = rng.randint(-127, 128, size=(spec["classes"], spec["features"])).astype(np.int8)
    x = rng.randint(0, 1 << spec["act_bits"], size=(spec["batch"], spec["img"], spec["img"], spec["in_ch"])).astype(
        np.uint8
    )
    logits = run(spec, convs, dense_w, x)
    assert logits.shape == (spec["batch"], spec["classes"])
    assert np.all(np.abs(logits) < 2**31), "logits overflow i32"

    out = bytearray()
    out += b"PGLD"
    out += struct.pack("<I", 1)
    out += struct.pack("<I", len(convs))
    for w in convs:
        out += struct.pack("<IIII", *w.shape)
        out += w.tobytes()
    out += struct.pack("<I", dense_w.size)
    out += dense_w.tobytes()
    out += struct.pack("<IIII", *x.shape)
    out += x.tobytes()
    out += struct.pack("<II", spec["batch"], spec["classes"])
    out += logits.astype("<i4").tobytes()

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"golden_{name}.bin"
    path.write_bytes(bytes(out))
    print(f"wrote {path} ({len(out)} bytes), logits[0] = {logits[0].tolist()}")


if __name__ == "__main__":
    for name, spec in SPECS.items():
        emit(name, spec)
