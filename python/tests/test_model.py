"""L2 tests: model shapes, quantization glue, training smoke, engine
agreement on the integer inference path."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import data
from compile.model import (
    ModelConfig,
    encode_input,
    forward_float_eval,
    forward_int,
    forward_train,
    init_params,
    loss_fn,
    quantize_model,
)
from compile.train import accuracy, train


@pytest.fixture(scope="module")
def tiny_trained():
    """A briefly-trained model shared across tests (module-scoped)."""
    cfg = ModelConfig()
    params, log = train(cfg, steps=120, train_n=1024, test_n=256, verbose=False)
    return cfg, params, log


class TestData:
    def test_shapes_and_range(self):
        x, y = data.make_dataset(32, seed=0)
        assert x.shape == (32, 16, 16, 1)
        assert y.shape == (32,)
        assert x.min() >= 0.0 and x.max() <= 1.0
        assert set(np.unique(y)).issubset(set(range(8)))

    def test_deterministic_by_seed(self):
        x1, y1 = data.make_dataset(16, seed=7)
        x2, y2 = data.make_dataset(16, seed=7)
        np.testing.assert_array_equal(x1, x2)
        np.testing.assert_array_equal(y1, y2)

    def test_classes_distinguishable(self):
        # mean images of different classes differ substantially
        x, y = data.make_dataset(512, seed=1, noise=0.05)
        means = [x[y == c].mean(axis=0) for c in range(8)]
        for a in range(8):
            for b in range(a + 1, 8):
                d = np.abs(means[a] - means[b]).mean()
                assert d > 0.02, f"classes {a},{b} too similar ({d})"


class TestTrainGraph:
    def test_forward_shapes(self):
        cfg = ModelConfig()
        params = init_params(jax.random.PRNGKey(0), cfg)
        x = jnp.zeros((4, 16, 16, 1), jnp.float32)
        logits = forward_train(params, x, cfg)
        assert logits.shape == (4, 8)

    def test_loss_finite_and_grad_nonzero(self):
        cfg = ModelConfig()
        params = init_params(jax.random.PRNGKey(0), cfg)
        x, y = data.make_dataset(16, seed=2)
        loss, grads = jax.value_and_grad(loss_fn)(
            params, jnp.asarray(x), jnp.asarray(y), cfg
        )
        assert np.isfinite(float(loss))
        gnorm = sum(float(jnp.sum(g**2)) for g in jax.tree_util.tree_leaves(grads))
        assert gnorm > 0.0

    def test_training_reduces_loss(self, tiny_trained):
        _, _, log = tiny_trained
        assert log[-1]["loss"] < log[0]["loss"]

    def test_training_beats_chance(self, tiny_trained):
        _, _, log = tiny_trained
        assert log[-1]["test_acc"] > 0.5, f"acc={log[-1]['test_acc']}"


class TestIntegerInference:
    def test_quantized_close_to_float(self, tiny_trained):
        cfg, params, _ = tiny_trained
        qm = quantize_model(params, cfg)
        x, y = data.make_dataset(256, seed=3)
        codes = encode_input(jnp.asarray(x), cfg.act_bits)
        int_acc = accuracy(forward_int(qm, codes), jnp.asarray(y))
        fq_acc = accuracy(forward_train(params, jnp.asarray(x), cfg), jnp.asarray(y))
        assert int_acc > fq_acc - 0.15, f"int={int_acc} fakequant={fq_acc}"

    def test_engines_agree_bitexact(self, tiny_trained):
        # pcilt / dm / segment integer paths must produce identical logits —
        # the paper's exactness claim end-to-end.
        cfg, params, _ = tiny_trained
        x, _ = data.make_dataset(16, seed=4)
        codes = encode_input(jnp.asarray(x), cfg.act_bits)
        outs = {}
        for engine in ("pcilt", "dm", "segment"):
            ecfg = ModelConfig(act_bits=cfg.act_bits, engine=engine, seg_n=2)
            qm = quantize_model(params, ecfg)
            outs[engine] = np.asarray(forward_int(qm, codes))
        np.testing.assert_array_equal(outs["pcilt"], outs["dm"])
        np.testing.assert_array_equal(outs["segment"], outs["dm"])

    def test_logits_are_int32(self, tiny_trained):
        cfg, params, _ = tiny_trained
        qm = quantize_model(params, cfg)
        x, _ = data.make_dataset(2, seed=5)
        out = forward_int(qm, encode_input(jnp.asarray(x), cfg.act_bits))
        assert out.dtype == jnp.int32
        assert out.shape == (2, 8)

    def test_encode_input_range(self):
        x = jnp.asarray(np.linspace(0, 1, 64, dtype=np.float32).reshape(1, 8, 8, 1))
        codes = encode_input(x, 4)
        assert codes.dtype == jnp.uint8
        assert int(codes.max()) == 15 and int(codes.min()) == 0

    def test_float_eval_baseline_shape(self, tiny_trained):
        cfg, params, _ = tiny_trained
        x, _ = data.make_dataset(4, seed=6)
        out = forward_float_eval(params, jnp.asarray(x), cfg)
        assert out.shape == (4, 8)
