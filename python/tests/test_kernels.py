"""L1 correctness: Pallas kernels vs the pure-jnp oracles (ref.py).

This is the CORE correctness signal of the python side: the PCILT kernel
must be bit-exact against DM (the paper's "no result precision loss"), and
hypothesis sweeps shapes/cardinalities.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.dm_conv import dm_conv
from compile.kernels.pcilt_conv import pcilt_conv
from compile.kernels.segment_conv import segment_conv

RNG = np.random.default_rng(42)


def rand_case(n, h, w, cin, cout, kh, kw, act_bits, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 1 << act_bits, size=(n, h, w, cin), dtype=np.uint8)
    wt = rng.integers(-127, 128, size=(cout, kh, kw, cin)).astype(np.int8)
    return jnp.asarray(x), jnp.asarray(wt)


class TestRefOracles:
    """The oracles agree among themselves first."""

    def test_pcilt_ref_equals_dm_ref(self):
        x, w = rand_case(2, 8, 8, 3, 4, 3, 3, 4, seed=1)
        tables = ref.build_tables(w, 4)
        np.testing.assert_array_equal(
            ref.conv2d_pcilt(x, tables, 3, 3), ref.conv2d_dm(x, w)
        )

    def test_segment_ref_equals_dm_ref(self):
        x, w = rand_case(1, 7, 7, 1, 2, 3, 3, 2, seed=2)
        st_ = ref.build_segment_tables(w, 2, 4)
        np.testing.assert_array_equal(
            ref.conv2d_segment(x, st_, 3, 3, 4, 2), ref.conv2d_dm(x, w)
        )

    def test_pack_offsets_little_endian(self):
        rf = jnp.asarray([[3, 0, 1, 2]], dtype=jnp.uint8)
        offs = ref.pack_offsets(rf, 4, 2)
        assert int(offs[0, 0]) == 3 | (1 << 4) | (2 << 6)

    def test_tables_shape_and_content(self):
        _, w = rand_case(1, 4, 4, 2, 3, 3, 3, 4, seed=3)
        t = ref.build_tables(w, 4)
        assert t.shape == (3, 18, 16)
        # spot check: position order is (ky,kx,ic)
        assert int(t[1, 0, 5]) == int(w[1, 0, 0, 0]) * 5
        assert int(t[2, 4, 3]) == int(w[2, 0, 2, 0]) * 3  # pos 4 = ky0,kx2,ic0

    def test_strided_dm_ref(self):
        x, w = rand_case(1, 9, 9, 2, 2, 3, 3, 4, seed=4)
        y = ref.conv2d_dm(x, w, stride=(2, 2))
        assert y.shape == (1, 4, 4, 2)
        # check one position by hand
        acc = sum(
            int(w[0, ky, kx, ic]) * int(x[0, 2 + ky, 4 + kx, ic])
            for ky in range(3)
            for kx in range(3)
            for ic in range(2)
        )
        assert int(y[0, 1, 2, 0]) == acc


class TestPciltKernel:
    def test_exact_vs_ref_small(self):
        x, w = rand_case(2, 8, 8, 2, 4, 3, 3, 4, seed=5)
        tables = ref.build_tables(w, 4)
        got = pcilt_conv(x, tables, 3, 3)
        np.testing.assert_array_equal(got, ref.conv2d_dm(x, w))

    def test_5x5_kernel(self):
        x, w = rand_case(1, 12, 10, 1, 3, 5, 5, 4, seed=6)
        tables = ref.build_tables(w, 4)
        np.testing.assert_array_equal(pcilt_conv(x, tables, 5, 5), ref.conv2d_dm(x, w))

    def test_bool_activations(self):
        x, w = rand_case(1, 6, 6, 2, 2, 3, 3, 1, seed=7)
        tables = ref.build_tables(w, 1)
        np.testing.assert_array_equal(pcilt_conv(x, tables, 3, 3), ref.conv2d_dm(x, w))

    @settings(max_examples=15, deadline=None)
    @given(
        n=st.integers(1, 2),
        hw=st.integers(5, 10),
        cin=st.integers(1, 3),
        cout=st.integers(1, 4),
        k=st.sampled_from([1, 3, 5]),
        act_bits=st.sampled_from([1, 2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_exactness_hypothesis(self, n, hw, cin, cout, k, act_bits, seed):
        if k > hw:
            return
        x, w = rand_case(n, hw, hw, cin, cout, k, k, act_bits, seed=seed)
        tables = ref.build_tables(w, act_bits)
        np.testing.assert_array_equal(
            pcilt_conv(x, tables, k, k), ref.conv2d_dm(x, w)
        )


class TestDmKernel:
    def test_exact_vs_ref(self):
        x, w = rand_case(2, 9, 7, 3, 4, 3, 3, 8, seed=8)
        np.testing.assert_array_equal(dm_conv(x, w, 3, 3), ref.conv2d_dm(x, w))

    def test_1x1_kernel(self):
        x, w = rand_case(1, 4, 4, 4, 8, 1, 1, 4, seed=9)
        np.testing.assert_array_equal(dm_conv(x, w, 1, 1), ref.conv2d_dm(x, w))

    @settings(max_examples=10, deadline=None)
    @given(
        hw=st.integers(4, 9),
        cin=st.integers(1, 3),
        cout=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_exactness_hypothesis(self, hw, cin, cout, seed):
        x, w = rand_case(1, hw, hw, cin, cout, 3, 3, 8, seed=seed)
        np.testing.assert_array_equal(dm_conv(x, w, 3, 3), ref.conv2d_dm(x, w))


class TestSegmentKernel:
    def test_boolhash_config(self):
        # The BoolHash configuration: bool acts, 8 per offset.
        x, w = rand_case(1, 8, 8, 1, 2, 5, 5, 1, seed=10)
        st_ = ref.build_segment_tables(w, 1, 8)
        got = segment_conv(x, st_, 5, 5, 8, 1)
        np.testing.assert_array_equal(got, ref.conv2d_dm(x, w))

    def test_int2_by_4(self):
        x, w = rand_case(2, 7, 7, 2, 3, 3, 3, 2, seed=11)
        st_ = ref.build_segment_tables(w, 2, 4)
        np.testing.assert_array_equal(
            segment_conv(x, st_, 3, 3, 4, 2), ref.conv2d_dm(x, w)
        )

    def test_seg_n_1_degenerates_to_pcilt(self):
        x, w = rand_case(1, 6, 6, 1, 2, 3, 3, 4, seed=12)
        st_ = ref.build_segment_tables(w, 4, 1)
        np.testing.assert_array_equal(
            segment_conv(x, st_, 3, 3, 1, 4), ref.conv2d_dm(x, w)
        )

    @settings(max_examples=10, deadline=None)
    @given(
        seg_n=st.sampled_from([1, 2, 4, 8]),
        act_bits=st.sampled_from([1, 2]),
        hw=st.integers(5, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_exactness_hypothesis(self, seg_n, act_bits, hw, seed):
        if seg_n * act_bits > 12:
            return
        x, w = rand_case(1, hw, hw, 1, 2, 3, 3, act_bits, seed=seed)
        st_ = ref.build_segment_tables(w, act_bits, seg_n)
        np.testing.assert_array_equal(
            segment_conv(x, st_, 3, 3, seg_n, act_bits), ref.conv2d_dm(x, w)
        )


class TestQuantizers:
    def test_unsigned_range(self):
        x = jnp.linspace(-1.0, 15.0, 50)
        q, scale = ref.quantize_unsigned(x, 15.0, 4)
        assert q.dtype == jnp.uint8
        assert int(q.min()) == 0 and int(q.max()) == 15
        assert float(scale) == pytest.approx(1.0)

    def test_symmetric_range(self):
        w = jnp.asarray([-2.0, -1.0, 0.0, 1.0, 2.0])
        q, scale = ref.quantize_symmetric(w, 4)
        assert q.dtype == jnp.int8
        assert int(q.min()) == -7 and int(q.max()) == 7
        assert float(scale) == pytest.approx(2.0 / 7.0)

    @settings(max_examples=20, deadline=None)
    @given(bits=st.sampled_from([2, 4, 8]), seed=st.integers(0, 10_000))
    def test_roundtrip_error_bounded(self, bits, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.normal(size=32).astype(np.float32))
        q, scale = ref.quantize_symmetric(w, bits)
        err = np.abs(np.asarray(q, np.float32) * float(scale) - np.asarray(w))
        assert err.max() <= float(scale) / 2 + 1e-6
