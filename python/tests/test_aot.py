"""AOT pipeline tests: HLO-text emission invariants and artifact-bundle
format compatibility with the rust loader.

The most important test here guards a silent-wrong-numbers regression we
hit during development: `as_hlo_text()` **elides large constants** as
`{...}` unless `print_large_constants=True`, and the HLO text parser then
reconstructs garbage tables (EXPERIMENTS.md §Perf L2)."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.aot import to_hlo_text
from compile.kernels import ref
from compile.kernels.pcilt_conv import pcilt_conv, vmem_footprint_bytes


class TestHloText:
    def test_large_constants_not_elided(self):
        # A function with a baked constant big enough to trigger elision.
        table = jnp.arange(16 * 72 * 16, dtype=jnp.int32).reshape(16, 72, 16)

        def fn(x):
            return (jnp.sum(table[:, 0, :] * x, axis=-1),)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((16,), jnp.int32))
        text = to_hlo_text(lowered)
        assert "{...}" not in text, "large constants elided — rust would load garbage"
        assert "HloModule" in text

    def test_pallas_kernel_lowers_to_hlo_text(self):
        x = np.random.default_rng(0).integers(0, 16, (1, 6, 6, 1), dtype=np.uint8)
        w = np.random.default_rng(1).integers(-127, 128, (2, 3, 3, 1)).astype(np.int8)
        tables = ref.build_tables(jnp.asarray(w), 4)

        def fn(codes):
            return (pcilt_conv(codes, tables, 3, 3),)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct(x.shape, jnp.uint8))
        text = to_hlo_text(lowered)
        assert "{...}" not in text
        # entry signature carries the uint8 input and int32 output
        assert "u8[1,6,6,1]" in text
        assert "s32[" in text

    def test_entry_returns_tuple(self):
        # rust unwraps with to_tuple1 — the lowering must return a 1-tuple.
        def fn(x):
            return (x + 1,)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
        text = to_hlo_text(lowered)
        first = text.splitlines()[0]
        assert "->(" in first.replace(" ", ""), f"not a tuple return: {first}"


class TestArtifactBundle:
    """Format checks against the built bundle (skipped if not built)."""

    @pytest.fixture(scope="class")
    def art_dir(self):
        d = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
        if not os.path.exists(os.path.join(d, "manifest.toml")):
            pytest.skip("artifacts not built")
        return d

    def test_manifest_has_required_keys(self, art_dir):
        text = open(os.path.join(art_dir, "manifest.toml")).read()
        for key in (
            "[model]",
            "act_bits",
            "[scales]",
            "s_in",
            "[weights]",
            "w1_len",
            "[artifacts]",
            "pcilt_b1",
        ):
            assert key in text, f"manifest missing {key}"

    def test_weight_lengths_consistent(self, art_dir):
        import re

        text = open(os.path.join(art_dir, "manifest.toml")).read()
        lens = {
            k: int(re.search(rf"{k} = (\d+)", text).group(1))
            for k in ("w1_len", "w2_len", "w3_len")
        }
        size = os.path.getsize(os.path.join(art_dir, "weights.bin"))
        assert size == sum(lens.values())

    def test_hlo_files_exist_and_unelided(self, art_dir):
        import re

        text = open(os.path.join(art_dir, "manifest.toml")).read()
        files = re.findall(r'= "(model_[^"]+\.hlo\.txt)"', text)
        assert len(files) >= 4
        for f in files:
            content = open(os.path.join(art_dir, f)).read()
            assert "{...}" not in content, f"{f} has elided constants"

    def test_smoke_pair_shapes(self, art_dir):
        codes = np.fromfile(os.path.join(art_dir, "smoke_input_b8.bin"), np.uint8)
        logits = np.fromfile(os.path.join(art_dir, "smoke_logits_b8.bin"), np.int32)
        labels = np.fromfile(os.path.join(art_dir, "smoke_labels_b8.bin"), np.int32)
        assert codes.size == 8 * 16 * 16
        assert logits.size == 8 * 8
        assert labels.size == 8
        assert codes.max() <= 15  # INT4 codes


class TestVmemModel:
    def test_footprint_small_enough_for_vmem(self):
        # DESIGN.md §Hardware-Adaptation: table bank must be VMEM-resident.
        for (h, w, cin, cout) in [(16, 16, 1, 8), (7, 7, 8, 16)]:
            b = vmem_footprint_bytes(h, w, cin, cout, 3, 3, 4)
            assert b < 16 * 1024 * 1024, f"footprint {b} exceeds VMEM budget"

    def test_footprint_scales_with_cardinality(self):
        a4 = vmem_footprint_bytes(16, 16, 8, 16, 3, 3, 4)
        a8 = vmem_footprint_bytes(16, 16, 8, 16, 3, 3, 8)
        assert a8 > a4
