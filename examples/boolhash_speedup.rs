//! E4 — the BoolHash experiment (Figs 5–6): boolean activations packed
//! N-at-a-time into PCILT offsets, measured against scalar DM on CPU.
//!
//! The authors' prior paper measured **6.59×** for N=8 on their test
//! network; this reproduces the *shape* of that result (monotone speedup
//! in N, same order of magnitude at N=8) on our hardware and network.
//!
//! Run with: `cargo run --release --example boolhash_speedup`

use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::{DmEngine, SegmentEngine};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::timing::{bench, BenchOpts};

fn main() {
    let mut rng = Rng::new(7);
    // Boolean activations, as in the BoolHash configuration.
    let x = Tensor4::random_activations(Shape4::new(1, 96, 96, 4), 1, &mut rng);
    let w = Tensor4::random_weights(Shape4::new(8, 5, 5, 4), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(5, 5);
    let opts = BenchOpts::default();

    let dm = DmEngine::new(w.clone(), geom);
    let y_ref = dm.conv(&x);
    let t_dm = bench("dm", &opts, || dm.conv(&x));
    println!("{}", t_dm.report());

    println!("\nsegment width sweep (bool activations):");
    println!(
        "{:<8} {:>12} {:>10} {:>14} {:>12}",
        "N", "p50", "speedup", "rows/segment", "add-ratio"
    );
    for n in [1usize, 2, 4, 8, 16] {
        let seg = SegmentEngine::new(&w, 1, n, geom);
        assert_eq!(seg.conv(&x), y_ref, "exactness lost at N={n}");
        let t = bench(&format!("segment-{n}"), &opts, || seg.conv(&x));
        let ops_dm = dm.op_counts(x.shape());
        let ops_seg = seg.op_counts(x.shape());
        println!(
            "{:<8} {:>12} {:>9.2}x {:>14} {:>11.1}x",
            n,
            pcilt::util::stats::fmt_ns(t.ns_per_iter()),
            t_dm.ns_per_iter() / t.ns_per_iter(),
            seg.seg_card,
            ops_dm.adds as f64 / ops_seg.adds as f64,
        );
    }
    println!(
        "\npaper (BoolHash, ref [73]): 6.59x at N=8 on their network — \
         compare the N=8 row's speedup column."
    );
}
