//! E2/E3 — the full ASIC comparison report: PCILT vs DM vs Winograd vs FFT
//! datapaths across activation cardinalities, plus the Fig 4 adder-tree
//! sweep and the SRAM/ROM table trade-off.
//!
//! Run with: `cargo run --release --example asic_report`

use pcilt::asic::{
    report::comparison_table, simulate_dm, simulate_fft, simulate_pcilt, simulate_segment,
    simulate_winograd, LayerWorkload, TableMem,
};
use pcilt::util::stats::fmt_count;

fn main() {
    let lanes = 16;
    let clock = 1.0;

    // --- E2: engine comparison at each activation cardinality ------------
    for act_bits in [1u32, 2, 4, 8] {
        let wl = LayerWorkload {
            act_bits,
            k: 3,
            ..LayerWorkload::default_small()
        };
        let mut reports = vec![
            simulate_dm(&wl, lanes),
            simulate_pcilt(&wl, lanes, 8, TableMem::Sram),
            simulate_pcilt(&wl, lanes, 8, TableMem::Rom),
        ];
        if act_bits <= 2 {
            reports.push(simulate_segment(
                &wl,
                lanes,
                (8 / act_bits) as usize,
                TableMem::Sram,
            ));
        }
        reports.push(simulate_winograd(&wl, lanes));
        reports.push(simulate_fft(&wl, lanes));
        comparison_table(
            &format!("E2: ASIC engines, INT{act_bits} activations"),
            &wl,
            &reports,
            clock,
        )
        .print();
    }

    // --- E3: adder-tree width sweep (Fig 4) ------------------------------
    println!("\n## E3: adder tree width sweep (Fig 4), INT4 activations");
    let wl = LayerWorkload {
        k: 3,
        ..LayerWorkload::default_small()
    };
    println!(
        "{:<8} {:>14} {:>10} {:>12}",
        "width", "cycles", "speedup", "adders/lane"
    );
    let base = simulate_pcilt(&wl, lanes, 1, TableMem::Sram).cycles;
    for width in [1usize, 2, 4, 8, 16, 32] {
        let r = simulate_pcilt(&wl, lanes, width, TableMem::Sram);
        println!(
            "{:<8} {:>14} {:>9.2}x {:>12}",
            width,
            fmt_count(r.cycles as u128),
            base as f64 / r.cycles as f64,
            2 * width - 1,
        );
    }

    // --- energy-per-output crossover vs cardinality ----------------------
    println!("\n## E2b: PCILT vs DM energy/output as cardinality grows");
    println!(
        "{:<10} {:>14} {:>14} {:>10}",
        "act_bits", "pcilt pJ/out", "dm pJ/out", "winner"
    );
    for act_bits in [1u32, 2, 4, 6, 8] {
        let wl = LayerWorkload {
            act_bits,
            k: 3,
            ..LayerWorkload::default_small()
        };
        let p = simulate_pcilt(&wl, lanes, 8, TableMem::Rom);
        let d = simulate_dm(&wl, lanes);
        let (pe, de) = (p.energy_per_output(&wl), d.energy_per_output(&wl));
        println!(
            "{:<10} {:>14.2} {:>14.2} {:>10}",
            act_bits,
            pe,
            de,
            if pe < de { "pcilt" } else { "dm" }
        );
    }
    println!(
        "\nThe paper's claim holds where it claims it: low-cardinality \
         activations. See EXPERIMENTS.md §E2."
    );
}
