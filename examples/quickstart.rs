//! Quickstart: build PCILTs for a small conv layer, run the lookup
//! convolution, and verify it is bit-exact against direct multiplication —
//! the paper's core claim in ~40 lines.
//!
//! Run with: `cargo run --example quickstart`

use pcilt::pcilt::engine::{ConvEngine, ConvGeometry};
use pcilt::pcilt::{DmEngine, PciltEngine};
use pcilt::tensor::{Shape4, Tensor4};
use pcilt::util::prng::Rng;
use pcilt::util::stats::fmt_count;

fn main() {
    let mut rng = Rng::new(42);

    // A 32x32 4-bit activation map with 8 channels...
    let act_bits = 4;
    let x = Tensor4::random_activations(Shape4::new(1, 32, 32, 8), act_bits, &mut rng);
    // ...and a 16-filter 5x5 INT8 conv layer.
    let w = Tensor4::random_weights(Shape4::new(16, 5, 5, 8), 8, &mut rng);
    let geom = ConvGeometry::unit_stride(5, 5);

    // Classic direct multiplication:
    let dm = DmEngine::new(w.clone(), geom);
    let y_dm = dm.conv(&x);

    // PCILT: pre-calculate every product once (Fig 1)...
    let pcilt = PciltEngine::new(&w, act_bits, geom);
    println!(
        "built PCILTs: {} tables x {} entries ({} one-off multiplications)",
        pcilt.tables().out_ch * pcilt.tables().positions,
        pcilt.tables().card,
        fmt_count(pcilt.build_evals() as u128),
    );

    // ...then inference is lookups + adds, no multiplications (Fig 2/3):
    let y_pcilt = pcilt.conv(&x);
    let ops = pcilt.op_counts(x.shape());
    println!(
        "inference ops: {} mults, {} adds, {} fetches",
        ops.mults,
        fmt_count(ops.adds as u128),
        fmt_count(ops.fetches as u128)
    );
    assert_eq!(ops.mults, 0);

    // The results are identical — "there is no result precision loss".
    assert_eq!(y_pcilt, y_dm);
    println!(
        "PCILT == DM on all {} outputs: exact ✓",
        fmt_count(y_dm.shape().len() as u128)
    );
}
