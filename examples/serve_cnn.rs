//! E11 — the end-to-end serving validation (DESIGN.md §6).
//!
//! Loads the trained QuantCNN artifact bundle (`make artifacts`), starts
//! the coordinator with PJRT workers + dynamic batching, drives a Poisson
//! open-loop workload, reports p50/p99 latency and throughput, and
//! cross-checks a sample of responses bit-for-bit against the rust-native
//! PCILT engine. Also runs the same workload on the native PCILT pool for
//! an engine-vs-engine comparison.
//!
//! Run with: `cargo run --release --example serve_cnn` (after
//! `make artifacts`).

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use pcilt::coordinator::{run_poisson, BackendSpec, NativeEngineKind, Server, ServerOpts};
use pcilt::model::{EngineChoice, QuantCnn};
use pcilt::runtime::ArtifactBundle;
use pcilt::util::error as anyhow;

fn main() -> anyhow::Result<()> {
    pcilt::util::logger::init();
    let dir = std::env::var("PCILT_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let bundle = ArtifactBundle::load(Path::new(&dir))
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    println!(
        "loaded bundle: QuantCNN act_bits={} trained test-acc={:.3}",
        bundle.params.act_bits, bundle.final_test_acc
    );

    let opts = ServerOpts {
        workers: 4,
        max_batch: 8,
        batch_deadline: Duration::from_micros(2_000),
        queue_capacity: 1024,
    };
    let rate = 2_000.0;
    let total = 4_000;
    let img = bundle.params.img;
    let act_bits = bundle.params.act_bits;

    // --- correctness spot-check before load: server answers == native ---
    let server = Arc::new(Server::start(
        BackendSpec::hlo(bundle.clone(), "pcilt"),
        &opts,
    )?);
    server.warmup(8, img)?; // absorb PJRT compile in the workers
    let native = QuantCnn::new(bundle.params.clone(), EngineChoice::Pcilt);
    let (codes, _, labels) = bundle.smoke_pair()?;
    let mut correct = 0;
    for i in 0..8 {
        // slice image i out of the smoke batch
        let mut one = pcilt::tensor::Tensor4::<u8>::zeros(pcilt::tensor::Shape4::new(
            1, img, img, 1,
        ));
        for h in 0..img {
            for w in 0..img {
                one.set(0, h, w, 0, codes.get(i, h, w, 0));
            }
        }
        let resp = server.infer_blocking(one.clone())?;
        let native_logits = native.forward(&one);
        anyhow::ensure!(
            resp.logits == native_logits[0],
            "served logits != native engine logits for smoke image {i}"
        );
        if resp.class == labels[i] as usize {
            correct += 1;
        }
    }
    println!("served answers == rust-native PCILT engine: OK (bit-exact, 8/8)");
    println!("smoke-batch classification: {correct}/8 correct");

    // --- load test: PJRT pool -------------------------------------------
    println!("\n=== PJRT (hlo) pool: Poisson {rate} rps, {total} requests ===");
    server.warmup(8, img)?;
    let report = run_poisson(&server, rate, total, img, act_bits, 0xE2E);
    let m = server.metrics();
    println!(
        "offered {} ({:.0} rps), shed {}",
        report.offered, report.offered_rps, report.rejected
    );
    println!("{}", m.report());
    drop(server);

    // --- same workload on the rust-native PCILT engine pool --------------
    println!("\n=== native PCILT pool: Poisson {rate} rps, {total} requests ===");
    let server2 = Arc::new(Server::start(
        BackendSpec::native(bundle.params.clone(), NativeEngineKind::Pcilt),
        &opts,
    )?);
    server2.warmup(8, img)?;
    let report2 = run_poisson(&server2, rate, total, img, act_bits, 0xE2E);
    let m2 = server2.metrics();
    println!(
        "offered {} ({:.0} rps), shed {}",
        report2.offered, report2.offered_rps, report2.rejected
    );
    println!("{}", m2.report());

    println!("\nE11 complete — record these numbers in EXPERIMENTS.md §E11.");
    Ok(())
}
