//! E6/E7 — the PCILT memory planner: reproduces every in-text memory
//! number from the paper and then explores the design space (activation
//! cardinality x value width x sharing) for a user-defined network.
//!
//! Pass a config file with a `[network]` section to plan your own CNN:
//! `cargo run --example memory_planner -- mynet.toml`

use pcilt::config::toml::Document;
use pcilt::config::network_from_document;
use pcilt::pcilt::memory::{
    basic_pcilt_bytes, build_mults_per_filter, dm_mults, paper_memory_report, shared_pcilt_bytes,
    NetworkSpec,
};
use pcilt::util::stats::{fmt_bytes, fmt_count};

fn main() {
    // --- paper reproduction ----------------------------------------------
    println!("## Paper's in-text claims vs this model (E6/E7)\n");
    println!(
        "{:<52} {:>12} {:>12} {:>7}",
        "configuration", "ours", "paper", "ratio"
    );
    for row in paper_memory_report() {
        let paper = row.paper_bytes.unwrap();
        println!(
            "{:<52} {:>12} {:>12} {:>6.2}x",
            row.label,
            fmt_bytes(row.ours_bytes),
            fmt_bytes(paper),
            row.ours_bytes / paper
        );
    }
    println!(
        "\nbuild cost: {} mults once vs {} DM mults (10k 1024x768 frames, 5x5)",
        fmt_count(build_mults_per_filter(5, 1, 8) as u128),
        fmt_count(dm_mults(10_000, 768, 1024, 5) as u128)
    );

    // --- user network (or the paper's) ------------------------------------
    let net = match std::env::args().nth(1) {
        Some(path) => {
            let text = std::fs::read_to_string(&path).expect("reading config");
            let doc = Document::parse(&text).expect("parsing config");
            network_from_document(&doc).expect("bad [network] section")
        }
        None => NetworkSpec::paper_example(),
    };
    println!(
        "\n## Design-space sweep for network {:?} (k={}, w{} bits)\n",
        net.filters, net.kernel, net.weight_bits
    );
    println!(
        "{:<10} {:>14} {:>14} {:>16}",
        "act_bits", "16-bit values", "natural width", "shared (32 vals)"
    );
    for bits in [1u32, 2, 4, 8] {
        let n = net.with_activation_bits(bits);
        println!(
            "{:<10} {:>14} {:>14} {:>16}",
            bits,
            fmt_bytes(basic_pcilt_bytes(&n, 16)),
            fmt_bytes(basic_pcilt_bytes(&n, n.product_bits())),
            fmt_bytes(shared_pcilt_bytes(32, &[bits], n.product_bits(), false)),
        );
    }
    println!(
        "\nweights: {} | products are {}+{} bits wide",
        fmt_count(net.weight_count() as u128),
        net.weight_bits,
        net.activation_bits
    );
}
